"""Trip-count-aware HLO cost analyzer: validated against unrolled ground
truth (the property the XLA built-in breaks on)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze, breakdown

M = 256


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_flops_match_unrolled():
    W = jax.ShapeDtypeStruct((8, M, M), jnp.float32)
    x = jax.ShapeDtypeStruct((M, M), jnp.float32)

    def scanned(w, x):
        return jax.lax.scan(lambda h, wi: (h @ wi, None), x, w)[0]

    def unrolled(w, x):
        for i in range(8):
            x = x @ w[i]
        return x

    t_scan = analyze(_compile(scanned, W, x).as_text())
    t_unroll = analyze(_compile(unrolled, W, x).as_text())
    expect = 8 * 2 * M ** 3
    assert t_scan.flops == pytest.approx(expect, rel=0.01)
    assert t_unroll.flops == pytest.approx(expect, rel=0.01)
    assert t_scan.while_trips == [8]


def test_grad_of_scan_counts_backward():
    W = jax.ShapeDtypeStruct((4, M, M), jnp.float32)
    x = jax.ShapeDtypeStruct((M, M), jnp.float32)

    def loss(w, x):
        return jax.lax.scan(lambda h, wi: (h @ wi, None), x, w)[0].sum()

    t = analyze(_compile(jax.grad(loss), W, x).as_text())
    # fwd (1 dot) + bwd (2 dots) per step
    expect = 3 * 4 * 2 * M ** 3
    assert t.flops == pytest.approx(expect, rel=0.05)


def test_dot_general_batched_flops():
    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    t = analyze(_compile(lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
                         a, b).as_text())
    assert t.flops == pytest.approx(2 * 4 * 32 * 64 * 16, rel=0.01)


def test_dus_in_loop_not_charged_full_buffer():
    """Stacking one row per iteration must cost O(row) per iteration,
    not O(buffer) (XLA-CPU wraps the DUS in convert fusions)."""
    x = jax.ShapeDtypeStruct((64, M), jnp.float32)

    def stack(x):
        def body(c, xi):
            return c, (xi * 2).astype(jnp.bfloat16)
        return jax.lax.scan(body, 0.0, x)[1]

    t = analyze(_compile(stack, x).as_text())
    buffer_bytes = 64 * M * 2
    # generous bound: a few row-passes, NOT 64 x buffer
    assert t.bytes < 20 * buffer_bytes, t.bytes


def test_collectives_inside_loop_multiplied():
    import os
    import subprocess
    import sys
    import textwrap
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_cost import analyze
        mesh = jax.make_mesh((4,), ("data",))
        W = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
        x = jax.ShapeDtypeStruct((4, 256), jnp.float32)
        def f(w, x):
            def body(h, wi):
                return jax.lax.with_sharding_constraint(
                    h @ wi, NamedSharding(mesh, P("data"))), None
            return jax.lax.scan(body, x, w)[0].sum()
        c = jax.jit(jax.grad(f), in_shardings=(
            NamedSharding(mesh, P(None, None, "data")),
            NamedSharding(mesh, P("data")))).lower(W, x).compile()
        t = analyze(c.as_text())
        total = sum(t.count_by_collective.values())
        assert total >= 8, t.count_by_collective
        print("OK", t.count_by_collective)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_breakdown_orders_by_cost():
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)

    def f(a):
        big = a @ a            # 2*512^3
        small = a[:64, :64] @ a[:64, :64]
        return big.sum() + small.sum()

    bd = breakdown(_compile(f, a).as_text(), top=5)
    assert bd["flops"][0][0] > bd["flops"][-1][0]
    assert bd["flops"][0][0] == pytest.approx(2 * 512 ** 3, rel=0.01)
