"""Publish-owned operand cache (runtime/operand_cache) and per-shard
routed fused lookup: publish/touch/seed semantics and the writer-order
contract, pull-mode epoch/refresh/rebuild semantics, grow-past-extent
re-stacks with live readers, routed-kernel parity for ``two_level``
vectors in {all-true, all-false, mixed}, the empty-batch
short-circuits, and cache coherence under concurrent async replays (no
torn stacks; a slice older than the epoch the gate certified is never
served; steady-state lookups patch zero slices)."""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import extendible_hashing as eh
from repro.core.sharded_eh import ShardedShortcutEH
from repro.kernels import eh_lookup as kmod
from repro.runtime.operand_cache import StackedOperandCache

from conftest import unique_keys


# ---------------------------------------------------------------------------
# Unit semantics of the cache itself.
# ---------------------------------------------------------------------------

class TestCacheUnit:
    def _parts(self, data, calls=None):
        def parts(s):
            if calls is not None:
                calls.append(s)
            return tuple(jnp.asarray(a) for a in data[s])
        return parts

    def test_build_hit_and_dirty_refresh(self):
        cache = StackedOperandCache(3)
        data = [(np.full((4,), s, np.int32), np.full((2, 2), s, np.float32))
                for s in range(3)]
        calls = []
        out = cache.get("fam", [0, 0, 0], self._parts(data, calls))
        assert sorted(calls) == [0, 1, 2]           # first build touches all
        assert cache.stats.rebuilds == 1
        np.testing.assert_array_equal(np.asarray(out[0])[1], 1)
        # clean get: parts never invoked, same arrays served
        calls.clear()
        out2 = cache.get("fam", [0, 0, 0], self._parts(data, calls))
        assert calls == [] and cache.stats.hits == 1
        assert all(a is b for a, b in zip(out, out2))
        # dirty shard 1: only its part is read, only its slice changes
        data[1] = (np.full((4,), 7, np.int32), np.full((2, 2), 7, np.float32))
        out3 = cache.get("fam", [0, 5, 0], self._parts(data, calls))
        assert calls == [1]
        assert cache.stats.slice_refreshes == 1
        np.testing.assert_array_equal(np.asarray(out3[0]),
                                      [[0] * 4, [7] * 4, [2] * 4])
        np.testing.assert_array_equal(np.asarray(out3[1])[0], 0.0)

    def test_stale_epoch_restores_refresh(self):
        """Epoch comparison is inequality, not order: a reader that
        recorded a newer tuple under an older epoch (the allowed race
        direction) refreshes again on the next get — never serves
        stale."""
        cache = StackedOperandCache(2)
        data = [(np.zeros(3, np.int32),), (np.zeros(3, np.int32),)]
        cache.get("f", [4, 0], self._parts(data))
        data[0] = (np.ones(3, np.int32),)
        out = cache.get("f", [5, 0], self._parts(data))
        np.testing.assert_array_equal(np.asarray(out[0])[0], 1)

    def test_shape_change_rebuilds_family(self):
        cache = StackedOperandCache(2)
        data = [(np.zeros((2, 2), np.float32),),
                (np.ones((2, 2), np.float32),)]
        cache.get("f", [0, 0], self._parts(data))
        # shard 0 doubled: both shards restack at the new shape
        data = [(np.zeros((4, 2), np.float32),),
                (np.ones((4, 2), np.float32),)]
        calls = []
        out = cache.get("f", [1, 0], self._parts(data, calls))
        assert cache.stats.rebuilds == 2
        assert sorted(calls) == [0, 1]
        assert out[0].shape == (2, 4, 2)
        # and the family is clean again at the new epochs
        cache.get("f", [1, 0], self._parts(data))
        assert cache.stats.hits == 1

    def test_failed_refresh_commits_nothing(self):
        """A parts() exception mid-refresh must not leave the entry
        claiming freshness for the shards patched before the failure:
        epochs and arrays commit together, after the whole loop."""
        cache = StackedOperandCache(2)
        data = [(np.zeros(3, np.int32),), (np.ones(3, np.int32),)]
        cache.get("f", [0, 0], self._parts(data))
        data[0] = (np.full(3, 5, np.int32),)

        def bad_parts(s):
            if s == 1:
                raise RuntimeError("boom")
            return tuple(jnp.asarray(a) for a in data[s])

        with pytest.raises(RuntimeError):       # both shards dirty
            cache.get("f", [1, 1], bad_parts)
        assert cache.epochs("f") == [0, 0]      # nothing committed
        out = cache.get("f", [1, 1], self._parts(data))
        np.testing.assert_array_equal(np.asarray(out[0]),
                                      [[5, 5, 5], [1, 1, 1]])

    def test_donate_flag_safe_on_cpu(self):
        """donate=True falls back to the non-donating refresh off
        accelerators; semantics are unchanged."""
        cache = StackedOperandCache(2, donate=True)
        data = [(np.zeros(3, np.int32),), (np.ones(3, np.int32),)]
        old = cache.get("f", [0, 0], self._parts(data))
        data[1] = (np.full(3, 9, np.int32),)
        out = cache.get("f", [0, 3], self._parts(data))
        np.testing.assert_array_equal(np.asarray(out[0]),
                                      [[0, 0, 0], [9, 9, 9]])
        # the pre-refresh loan is still readable (no donation on CPU)
        np.testing.assert_array_equal(np.asarray(old[0]),
                                      [[0, 0, 0], [1, 1, 1]])

    def test_epoch_arity_checked_and_invalidate(self):
        cache = StackedOperandCache(2)
        with pytest.raises(ValueError):
            cache.get("f", [0], lambda s: (jnp.zeros(1),))
        data = [(np.zeros(2, np.int32),), (np.zeros(2, np.int32),)]
        cache.get("f", [0, 0], self._parts(data))
        assert "f" in cache and cache.epochs("f") == [0, 0]
        cache.invalidate("f")
        assert "f" not in cache and cache.epochs("f") is None
        cache.get("f", [0, 0], self._parts(data))
        assert cache.stats.rebuilds == 2


# ---------------------------------------------------------------------------
# The publish path: writers patch the stack at publish time; the lookup
# path is an epoch check plus a handle return.
# ---------------------------------------------------------------------------

class TestPublishPath:
    def test_first_publish_creates_family_zeroed(self):
        cache = StackedOperandCache(3)
        cache.publish("v", 1, (jnp.full((4,), 7, jnp.int32),), epoch=5)
        assert cache.published("v") == [False, True, False]
        assert cache.epochs("v") == [0, 5, 0]
        stack, = cache.handle("v")
        np.testing.assert_array_equal(
            np.asarray(stack), [[0] * 4, [7] * 4, [0] * 4])
        assert cache.stats.publish_refreshes == 1
        assert cache.stats.rebuilds == 1          # the zeroed creation
        assert cache.resident_bytes()["v"] == stack.nbytes

    def test_get_without_parts_is_epoch_check_plus_handle(self):
        cache = StackedOperandCache(2)
        cache.publish("v", 0, (jnp.ones((2,), jnp.int32),), epoch=3)
        cache.publish("v", 1, (jnp.full((2,), 2, jnp.int32),), epoch=1)
        out = cache.get("v", [3, 1])
        assert out is cache.handle("v")           # the stack itself
        assert cache.stats.hits == 1
        assert cache.stats.lookup_refreshes == 0
        # a newer entry than requested is still a hit (allowed race
        # direction: publish landed between epoch read and get)
        assert cache.get("v", [2, 0]) is out

    def test_lagging_push_family_is_writer_order_violation(self):
        cache = StackedOperandCache(2)
        with pytest.raises(RuntimeError, match="never published"):
            cache.get("v", [0, 0])
        cache.publish("v", 0, (jnp.zeros((2,), jnp.int32),), epoch=1)
        with pytest.raises(RuntimeError, match="lags the reader"):
            cache.get("v", [1, 2])

    def test_touch_advances_epoch_without_data(self):
        cache = StackedOperandCache(2)
        cache.touch("v", 0, epoch=9)              # no family yet: no-op
        assert "v" not in cache
        cache.publish("v", 0, (jnp.ones((2,), jnp.int32),), epoch=1)
        before = cache.handle("v")
        cache.touch("v", 0, epoch=4)
        assert cache.epochs("v") == [4, 0]
        assert cache.handle("v") is before        # no device work
        cache.touch("v", 0, epoch=2)              # epochs only move forward
        assert cache.epochs("v") == [4, 0]

    def test_seed_publishes_every_shard(self):
        cache = StackedOperandCache(2)
        z = jnp.zeros((3, 2), jnp.float32)
        cache.seed("kv", [(z, z), (z, z)])
        assert cache.published("kv") == [True, True]
        assert cache.epochs("kv") == [0, 0]
        k, v = cache.get("kv", [0, 0])
        assert k.shape == (2, 3, 2) and v.shape == (2, 3, 2)

    def test_publish_validates_part_count_dtype_rank(self):
        cache = StackedOperandCache(2)
        cache.publish("v", 0, (jnp.zeros((2,), jnp.int32),), epoch=1)
        with pytest.raises(ValueError, match="parts for"):
            cache.publish("v", 0, (jnp.zeros((2,), jnp.int32),) * 2,
                          epoch=2)
        with pytest.raises(ValueError, match="dtypes changed"):
            cache.publish("v", 0, (jnp.zeros((2,), jnp.float32),), epoch=2)
        with pytest.raises(ValueError, match="ranks changed"):
            cache.publish("v", 0, (jnp.zeros((2, 2), jnp.int32),), epoch=2)
        with pytest.raises(ValueError, match="shard"):
            cache.publish("v", 2, (jnp.zeros((2,), jnp.int32),), epoch=2)

    def test_smaller_part_pads_to_extent(self):
        cache = StackedOperandCache(2)
        cache.publish("v", 0, (jnp.full((4,), 1, jnp.int32),), epoch=1)
        cache.publish("v", 1, (jnp.full((2,), 2, jnp.int32),), epoch=1)
        stack, = cache.get("v", [1, 1])
        np.testing.assert_array_equal(
            np.asarray(stack), [[1, 1, 1, 1], [2, 2, 0, 0]])

    def test_grow_past_extent_restacks_without_blocking_readers(self):
        """A part outgrowing the stacked extent embeds the old stack in
        a larger zeroed one and swaps atomically: the reader's old
        handle stays valid and bit-identical, the new stack carries the
        old slices at the origin plus the grown part."""
        cache = StackedOperandCache(2)
        cache.publish("v", 0, (jnp.full((2, 2), 3, jnp.int32),), epoch=1)
        cache.publish("v", 1, (jnp.full((2, 2), 4, jnp.int32),), epoch=1)
        old, = cache.get("v", [1, 1])
        old_copy = np.asarray(old).copy()
        built = cache.stats.rebuilds
        # shard 0 doubles its first axis (a directory doubling)
        cache.publish("v", 0, (jnp.full((4, 2), 5, jnp.int32),), epoch=2)
        assert cache.stats.rebuilds == built + 1
        np.testing.assert_array_equal(np.asarray(old), old_copy)
        new, = cache.get("v", [2, 1])
        assert new.shape == (2, 4, 2)
        np.testing.assert_array_equal(np.asarray(new[0]), 5)
        # shard 1 kept its data, zero-padded past its own extent
        np.testing.assert_array_equal(np.asarray(new[1][:2]), 4)
        np.testing.assert_array_equal(np.asarray(new[1][2:]), 0)
        assert cache.resident_bytes()["v"] == new.nbytes

    def test_slice_of_memoized_per_publish(self):
        cache = StackedOperandCache(2)
        assert cache.slice_of("v", 0) is None
        cache.publish("v", 0, (jnp.full((3,), 1, jnp.int32),), epoch=1)
        s1 = cache.slice_of("v", 0)
        assert cache.slice_of("v", 0) is s1       # steady state: memo hit
        np.testing.assert_array_equal(np.asarray(s1[0]), 1)
        cache.publish("v", 1, (jnp.full((3,), 2, jnp.int32),), epoch=1)
        s2 = cache.slice_of("v", 0)
        assert s2 is not s1                       # stack swapped: new slice
        np.testing.assert_array_equal(np.asarray(s2[0]), 1)
        np.testing.assert_array_equal(
            np.asarray(cache.slice_of("v", 1)[0]), 2)

    def test_publish_if_present_only_warms_existing(self):
        cache = StackedOperandCache(2)
        calls = []

        def parts():
            calls.append(1)
            return (jnp.zeros((2,), jnp.int32),)

        cache.publish_if_present("t", 0, parts, epoch=1)
        assert calls == [] and "t" not in cache   # never built: no cost
        cache.get("t", [0, 0],
                  lambda s: (jnp.full((2,), s, jnp.int32),))
        cache.publish_if_present("t", 0, parts, epoch=1)
        assert calls == [1] and cache.epochs("t") == [1, 0]

    def test_invalidate_resets_published_flags_and_resident(self):
        cache = StackedOperandCache(2)
        cache.publish("v", 0, (jnp.zeros((2,), jnp.int32),), epoch=1)
        cache.invalidate("v")
        assert cache.published("v") is None
        assert "v" not in cache.resident_bytes()
        assert cache.slice_of("v", 0) is None

    def test_concurrent_readers_during_publish_churn(self):
        """One writer thread publishes growing slices while readers spin
        on slice_of/get: every observed slice must be internally
        consistent (keys and vals from the SAME publication) and the
        epoch contract must hold — get at an epoch the writer already
        stored never raises and never serves older data."""
        cache = StackedOperandCache(2)
        cache.publish("v", 0, (jnp.zeros((4,), jnp.int32),
                               jnp.zeros((4,), jnp.int32)), epoch=0)
        cache.publish("v", 1, (jnp.zeros((4,), jnp.int32),
                               jnp.zeros((4,), jnp.int32)), epoch=0)
        published = [0, 0]                        # writer-side epochs
        errors = []
        stop = threading.Event()

        def reader():
            try:
                while not stop.is_set():
                    eps = list(published)         # epochs BEFORE get
                    k, v = cache.get("v", eps)
                    for s in range(2):
                        a, b = np.asarray(k[s]), np.asarray(v[s])
                        assert np.array_equal(b, -a), "torn slice"
                        # each publication's first element IS its epoch
                        assert a[0] >= eps[s], \
                            "stale slice served past its epoch"
                    sl = cache.slice_of("v", 0)
                    assert np.array_equal(np.asarray(sl[1]),
                                          -np.asarray(sl[0]))
            except Exception as e:                # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=reader) for _ in range(2)]
        for t in threads:
            t.start()
        try:
            for e in range(1, 40):
                s = e % 2
                n = 4 + (e // 8) * 2              # periodic growth
                a = jnp.arange(e, e + n, dtype=jnp.int32)
                cache.publish("v", s, (a, -a), epoch=e)
                published[s] = e                  # arrays before epochs
        finally:
            stop.set()
            for t in threads:
                t.join(timeout=60.0)
        assert not errors, errors
        assert cache.stats.lookup_refreshes == 0  # readers never patched


# ---------------------------------------------------------------------------
# Routed kernel parity: per-shard two_level in {all-true, all-false, mixed}.
# ---------------------------------------------------------------------------

def _stacked_shards(rng, n_shards, keys_per_shard=160):
    """N independent EH states + composed views, stacked (views padded
    to the common slot capacity, exactly as the cache does)."""
    states, views, probes = [], [], []
    for s in range(n_shards):
        st = eh.eh_create(8, 8, 256)
        k = unique_keys(rng, keys_per_shard)
        v = (np.arange(keys_per_shard, dtype=np.uint32)
             + np.uint32(s * 10_000))
        st = eh.eh_insert_many(st, jnp.asarray(k), jnp.asarray(v))
        vs = max(1, 1 << int(st.global_depth))
        vk, vv = eh.compose_shortcut(st, vs)
        states.append(st)
        views.append((vk, vv, vs.bit_length() - 1))
        probes.append(k[:64])
    v_cap = max(v[0].shape[0] for v in views)
    pads = [(jnp.pad(v[0], ((0, v_cap - v[0].shape[0]), (0, 0))),
             jnp.pad(v[1], ((0, v_cap - v[1].shape[0]), (0, 0))), v[2])
            for v in views]
    ops = dict(
        keys=jnp.stack([jnp.asarray(p, jnp.uint32) for p in probes]),
        dirs=jnp.stack([st.directory for st in states]),
        bks=jnp.stack([st.bucket_keys for st in states]),
        bvs=jnp.stack([st.bucket_vals for st in states]),
        gds=jnp.asarray([int(st.global_depth) for st in states], jnp.int32),
        vks=jnp.stack([p[0] for p in pads]),
        vvs=jnp.stack([p[1] for p in pads]),
        vls=jnp.asarray([p[2] for p in pads], jnp.int32))
    return ops


class TestRoutedKernelParity:
    @pytest.mark.parametrize("flags", [
        [1, 1, 1, 1],                    # all-true: every shard two-level
        [0, 0, 0, 0],                    # all-false: every shard shortcut
        [1, 0, 0, 1], [0, 1, 1, 0],      # mixed-sync groups
    ])
    def test_matches_static_kernels(self, rng, flags):
        o = _stacked_shards(rng, 4)
        ref = kmod.sharded_eh_lookup(o["keys"], o["dirs"], o["bks"],
                                     o["bvs"], o["gds"], tile=64)
        via_view = kmod.sharded_shortcut_lookup(o["keys"], o["vks"],
                                                o["vvs"], o["vls"], tile=64)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(via_view))
        got = kmod.sharded_routed_lookup(
            o["keys"], o["dirs"], o["bks"], o["bvs"], o["gds"],
            o["vks"], o["vvs"], o["vls"],
            jnp.asarray(flags, jnp.int32), tile=64)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_stacked_single_shard_select_matches_flat(self, rng):
        """The bound single-shard path (``stacked_shortcut_lookup``):
        scalar-prefetched shard id selects one slice of the stacked
        views inside the kernel — parity with the flat per-shard
        shortcut lookup, including misses, for every shard."""
        o = _stacked_shards(rng, 4)
        for s in range(4):
            keys = jnp.concatenate([
                o["keys"][s],
                jnp.asarray(unique_keys(rng, 40, lo=2**31, hi=2**32 - 2),
                            jnp.uint32)])
            ref = eh.shortcut_lookup_many(
                o["vks"][s], o["vvs"][s], int(o["vls"][s]), keys)
            got = kmod.stacked_shortcut_lookup(
                keys, o["vks"], o["vvs"], o["vls"], s, tile=64)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_slot_width_mismatch_rejected(self, rng):
        o = _stacked_shards(rng, 2)
        with pytest.raises(ValueError, match="slot widths"):
            kmod.sharded_routed_lookup(
                o["keys"], o["dirs"], o["bks"], o["bvs"], o["gds"],
                o["vks"][:, :, :4], o["vvs"][:, :, :4], o["vls"],
                jnp.zeros(2, jnp.int32), tile=64)


# ---------------------------------------------------------------------------
# The cached sharded index end to end.
# ---------------------------------------------------------------------------

def _count_kernels(monkeypatch):
    """Wrap the three sharded kernel entry points with call counters
    (lookup_batched imports them from the module at call time)."""
    counts = {"trad": 0, "shortcut": 0, "routed": 0}
    for name, attr in (("trad", "sharded_eh_lookup"),
                       ("shortcut", "sharded_shortcut_lookup"),
                       ("routed", "sharded_routed_lookup")):
        orig = getattr(kmod, attr)

        def wrapper(*a, _orig=orig, _name=name, **kw):
            counts[_name] += 1
            return _orig(*a, **kw)

        monkeypatch.setattr(kmod, attr, wrapper)
    return counts


class TestCachedShardedLookup:
    def test_steady_state_hits_cache(self, rng):
        keys = unique_keys(rng, 600)
        vals = np.arange(600, dtype=np.uint32)
        with ShardedShortcutEH(12, 8, 2048, num_shards=4) as idx:
            idx.insert(keys, vals)
            idx.pump()
            np.testing.assert_array_equal(
                np.asarray(idx.lookup_batched(keys)), vals)
            built = idx.operands.stats.rebuilds
            pubs = idx.operands.stats.publish_refreshes
            for _ in range(3):          # unchanged index: zero device work
                np.testing.assert_array_equal(
                    np.asarray(idx.lookup_batched(keys)), vals)
            assert idx.operands.stats.hits >= 3
            assert idx.operands.stats.rebuilds == built
            assert idx.operands.stats.publish_refreshes == pubs
            # THE acceptance invariant: refreshes moved off the lookup
            # path entirely — replays published at write time instead
            assert idx.operands.stats.lookup_refreshes == 0
            assert pubs > 0

    def test_refresh_happens_at_publish_not_lookup(self, rng):
        keys = unique_keys(rng, 600)
        vals = np.arange(600, dtype=np.uint32)
        with ShardedShortcutEH(12, 8, 2048, num_shards=4) as idx:
            idx.insert(keys, vals)
            idx.pump()
            idx.lookup_batched(keys)                  # warm
            # dirty exactly one shard (a single-key insert touches only
            # the owning shard's mapper and state)
            target = unique_keys(rng, 1, lo=2**31, hi=2**32 - 2)
            idx.insert(target, np.asarray([999_999], np.uint32))
            pubs = idx.operands.stats.publish_refreshes
            idx.pump()                                # replay publishes HERE
            assert idx.operands.stats.publish_refreshes > pubs
            out = np.asarray(idx.lookup_batched(
                np.concatenate([keys, target])))
            np.testing.assert_array_equal(out[:-1], vals)
            assert out[-1] == 999_999
            # the lookup itself patched nothing: the slice landed on the
            # mapper thread at publish time, before sc_version moved
            assert idx.operands.stats.lookup_refreshes == 0

    def test_gate_certified_view_never_stale(self, rng):
        """Insert → pump → lookup must see the new key through the
        cached shortcut path: the replay bumped the shard's epoch before
        publishing the version the gate certifies, so the cache cannot
        serve the pre-insert slice."""
        keys = unique_keys(rng, 400)
        vals = np.arange(400, dtype=np.uint32)
        with ShardedShortcutEH(12, 8, 2048, num_shards=2) as idx:
            idx.insert(keys[:200], vals[:200])
            idx.pump()
            idx.lookup_batched(keys[:200])            # warm both families
            for i in range(200, 400, 50):
                idx.insert(keys[i:i + 50], vals[i:i + 50])
                idx.pump()
                assert idx.in_sync()
                got = np.asarray(idx.lookup_batched(keys[:i + 50]))
                np.testing.assert_array_equal(got, vals[:i + 50])

    def test_mixed_gates_resolve_in_one_routed_dispatch(
            self, rng, monkeypatch):
        keys = unique_keys(rng, 800)
        vals = np.arange(800, dtype=np.uint32)
        with ShardedShortcutEH(12, 8, 2048, num_shards=4) as idx:
            idx.insert(keys, vals)
            idx.pump()
            assert idx.in_sync()
            # shards 1 and 2 refuse the shortcut (threshold below any
            # possible fan-in), 0 and 3 accept
            idx.shards[1].fan_in_threshold = -1.0
            idx.shards[2].fan_in_threshold = -1.0
            counts = _count_kernels(monkeypatch)
            misses = unique_keys(rng, 100, lo=2**31, hi=2**32 - 2)
            probe = np.concatenate([keys, misses])
            got = np.asarray(idx.lookup_batched(probe))
            assert counts == {"trad": 0, "shortcut": 0, "routed": 1}, \
                "a mixed-sync group must fuse into ONE routed dispatch"
            expect = np.concatenate(
                [vals, np.full(100, 0xFFFFFFFF, np.uint32)])
            np.testing.assert_array_equal(got, expect)
            # flipping every shard traditional uses the static kernel
            for s in idx.shards:
                s.fan_in_threshold = -1.0
            got = np.asarray(idx.lookup_batched(probe))
            np.testing.assert_array_equal(got, expect)
            assert counts["trad"] == 1 and counts["routed"] == 1

    def test_empty_batch_short_circuits(self, rng, monkeypatch):
        keys = unique_keys(rng, 200)
        with ShardedShortcutEH(12, 8, 2048, num_shards=2) as idx:
            idx.insert(keys, np.arange(200, dtype=np.uint32))
            idx.pump()
            counts = _count_kernels(monkeypatch)
            routed = (idx.routed_shortcut, idx.routed_traditional)
            stats = idx.operands.stats.snapshot()
            out = idx.lookup_batched(np.empty(0, np.uint32))
            assert out.shape == (0,) and out.dtype == jnp.uint32
            out = idx.lookup(np.empty(0, np.uint32))
            assert out.shape == (0,)
            assert sum(counts.values()) == 0          # no dispatch at all
            assert (idx.routed_shortcut, idx.routed_traditional) == routed
            after = idx.operands.stats                # cache untouched
            assert (after.hits, after.rebuilds, after.slice_refreshes) == \
                (stats.hits, stats.rebuilds, stats.slice_refreshes)


class TestKVEmptyBatch:
    def test_get_context_empty_returns_without_device_work(self, rng):
        from repro.kvcache import paged_cache as pc
        from repro.kvcache.shortcut_cache import ShortcutKVManager
        L, nb, bs, KV, hd, max_seqs, cap = 2, 32, 4, 2, 8, 4, 32
        cache = pc.cache_create(L, nb, bs, KV, hd, max_seqs, cap // bs,
                                dtype=jnp.float32)
        with ShortcutKVManager(cache, seq_capacity=cap,
                               num_shards=2) as mgr:
            routed = (mgr.routed_shortcut, mgr.routed_paged)
            k, v, route = mgr.get_context(np.empty(0, np.int64))
            assert k.shape == (L, 0, KV, cap, hd)
            assert v.shape == (L, 0, KV, cap, hd)
            assert route in ("shortcut", "paged")
            assert (mgr.routed_shortcut, mgr.routed_paged) == routed
            # an explicitly requested route is echoed back
            _, _, route = mgr.get_context(np.empty(0, np.int64),
                                          route="shortcut")
            assert route == "shortcut"


# ---------------------------------------------------------------------------
# Cache coherence under concurrent async replays (satellite acceptance:
# randomized parity with mappers publishing mid-stream; no torn stacks;
# a slice older than the gate-certified epoch is never served).
# ---------------------------------------------------------------------------

class TestAsyncCoherence:
    @pytest.mark.parametrize("num_shards", [2, 4])
    def test_randomized_parity_with_publishing_mappers(self, rng,
                                                       num_shards):
        """Inserts are synchronous (authoritative), replays land on the
        mapper threads whenever they land: every batched lookup must
        still read its own writes — the version gate demotes stale
        shards to the traditional path per shard, and any shortcut slice
        the cache serves must be at least as new as the gate certified.
        A torn stack (keys slice from one publication, vals from
        another) or a stale cached slice breaks oracle parity."""
        keys = unique_keys(rng, 900)
        vals = np.arange(900, dtype=np.uint32)
        misses = unique_keys(rng, 120, lo=2**31, hi=2**32 - 2)
        oracle = {}
        idx = ShardedShortcutEH(12, 8, 2048, num_shards=num_shards,
                                async_mapper=True, poll_interval=0.001)
        try:
            step = 90
            for i in range(0, 900, step):
                kb, vb = keys[i:i + step], vals[i:i + step]
                idx.insert(kb, vb)
                oracle.update(zip(kb.tolist(), vb.tolist()))
                probe = np.concatenate([keys[:i + step], misses])
                perm = rng.permutation(probe.size)
                probe = probe[perm]
                expect = np.asarray(
                    [oracle.get(int(k), 0xFFFFFFFF) for k in probe],
                    np.uint32)
                for _ in range(3):      # replays race these lookups
                    got = np.asarray(idx.lookup_batched(probe))
                    np.testing.assert_array_equal(got, expect)
            assert idx.wait_in_sync(timeout=60.0)
            got = np.asarray(idx.lookup_batched(keys))
            np.testing.assert_array_equal(got, vals)
            # the steady-state read after sync is served from cache
            h0 = idx.operands.stats.hits
            np.testing.assert_array_equal(
                np.asarray(idx.lookup_batched(keys)), vals)
            assert idx.operands.stats.hits > h0
        finally:
            idx.close()

    def test_concurrent_readers_share_cache_consistently(self, rng):
        """Two reader threads hammer lookup_batched while the main
        thread inserts and async mappers replay: the cache lock must
        keep every served stack internally consistent (parity holds in
        every reader at every step)."""
        keys = unique_keys(rng, 600)
        vals = np.arange(600, dtype=np.uint32)
        idx = ShardedShortcutEH(12, 8, 2048, num_shards=2,
                                async_mapper=True, poll_interval=0.001)
        idx.insert(keys[:300], vals[:300])
        idx.pump()
        errors = []
        stop = threading.Event()

        def reader(seed):
            r = np.random.default_rng(seed)
            known = keys[:300]
            try:
                while not stop.is_set():
                    probe = r.choice(known, 64)
                    got = np.asarray(idx.lookup_batched(probe))
                    want = np.asarray(
                        [vals[np.nonzero(keys == k)[0][0]] for k in probe],
                        np.uint32)
                    np.testing.assert_array_equal(got, want)
            except Exception as e:      # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=reader, args=(s,))
                   for s in (1, 2)]
        for t in threads:
            t.start()
        try:
            for i in range(300, 600, 60):
                idx.insert(keys[i:i + 60], vals[i:i + 60])
        finally:
            stop.set()
            for t in threads:
                t.join(timeout=60.0)
            idx.close()
        assert not errors, errors
