"""Paged KV cache + shortcut view: allocation, equivalence, routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kvcache import paged_cache as pc
from repro.kvcache.shortcut_cache import ShortcutKVManager


def make_cache(L=2, nb=64, bs=4, kv=2, hd=8, max_seqs=4, mbps=16):
    return pc.cache_create(L, nb, bs, kv, hd, max_seqs, mbps,
                           dtype=jnp.float32)


def rand_kv(rng, L, B, S, KV, hd):
    return (jnp.asarray(rng.normal(size=(L, B, S, KV, hd)).astype(
        np.float32)),
        jnp.asarray(rng.normal(size=(L, B, S, KV, hd)).astype(np.float32)))


def test_prefill_then_gather_roundtrip(rng):
    cache = make_cache()
    k, v = rand_kv(rng, 2, 2, 8, 2, 8)
    cache = pc.write_prefill(cache, jnp.asarray([0, 1]), k, v)
    kc, vc = pc.gather_context(cache, jnp.asarray([0, 1]))
    kt = np.asarray(k).transpose(0, 1, 3, 2, 4)   # native layout
    vt = np.asarray(v).transpose(0, 1, 3, 2, 4)
    np.testing.assert_allclose(np.asarray(kc[:, :, :, :8]), kt)
    np.testing.assert_allclose(np.asarray(vc[:, :, :, :8]), vt)
    assert np.asarray(cache.seq_lens)[:2].tolist() == [8, 8]


def test_append_crosses_block_boundary(rng):
    cache = make_cache(bs=4)
    k, v = rand_kv(rng, 2, 1, 4, 2, 8)
    cache = pc.write_prefill(cache, jnp.asarray([0]), k, v)
    appended = []
    for t in range(6):  # crosses into blocks 2 and 3
        nk = jnp.asarray(rng.normal(size=(2, 1, 2, 8)).astype(np.float32))
        nv = jnp.asarray(rng.normal(size=(2, 1, 2, 8)).astype(np.float32))
        cache = pc.append_tokens(cache, jnp.asarray([0]), nk, nv)
        appended.append((nk, nv))
    assert int(cache.seq_lens[0]) == 10
    kc, _ = pc.gather_context(cache, jnp.asarray([0]))
    for t, (nk, _) in enumerate(appended):
        np.testing.assert_allclose(np.asarray(kc[:, 0, :, 4 + t]),
                                   np.asarray(nk[:, 0]))


def test_release_recycles_blocks(rng):
    cache = make_cache(nb=8, bs=4, mbps=4)
    k, v = rand_kv(rng, 2, 2, 8, 2, 8)
    cache = pc.write_prefill(cache, jnp.asarray([0, 1]), k, v)
    assert int(cache.free_count) == 4
    cache = pc.release_seqs(cache, jnp.asarray([0]))
    assert int(cache.free_count) == 6
    assert int(cache.seq_lens[0]) == 0
    # freed blocks are reusable
    cache = pc.write_prefill(cache, jnp.asarray([2]), k[:, :1], v[:, :1])
    assert int(cache.free_count) == 4


def test_fragmentation_statistic(rng):
    cache = make_cache(nb=32, bs=4)
    k, v = rand_kv(rng, 2, 1, 16, 2, 8)
    cache = pc.write_prefill(cache, jnp.asarray([0]), k, v)
    # fresh prefill allocates contiguous blocks -> fragmentation 0
    assert float(pc.fragmentation(cache, jnp.asarray([0]))) == 0.0


class TestShortcutManager:
    def test_paged_and_shortcut_context_agree(self, rng):
        cache = make_cache()
        mgr = ShortcutKVManager(cache, seq_capacity=64)
        k, v = rand_kv(rng, 2, 2, 8, 2, 8)
        mgr.prefill(np.array([0, 1]), k, v)
        assert not mgr.in_sync(np.array([0, 1]))
        mgr.pump()
        assert mgr.in_sync(np.array([0, 1]))
        kp, vp, _ = mgr.get_context(np.array([0, 1]), route="paged")
        ks, vs, _ = mgr.get_context(np.array([0, 1]), route="shortcut")
        sl = int(mgr.seq_lens(np.array([0]))[0])
        np.testing.assert_allclose(np.asarray(kp[:, :, :, :sl]),
                                   np.asarray(ks[:, :, :, :sl]))
        np.testing.assert_allclose(np.asarray(vp[:, :, :, :sl]),
                                   np.asarray(vs[:, :, :, :sl]))

    def test_append_keeps_view_in_sync(self, rng):
        cache = make_cache()
        mgr = ShortcutKVManager(cache, seq_capacity=64)
        k, v = rand_kv(rng, 2, 1, 4, 2, 8)
        mgr.prefill(np.array([0]), k, v)
        mgr.pump()
        for _ in range(5):
            nk = jnp.asarray(rng.normal(size=(2, 1, 2, 8)).astype(
                np.float32))
            nv = jnp.asarray(rng.normal(size=(2, 1, 2, 8)).astype(
                np.float32))
            mgr.append(np.array([0]), nk, nv)
        assert not mgr.in_sync(np.array([0]))
        mgr.pump()
        assert mgr.in_sync(np.array([0]))
        kp, vp, _ = mgr.get_context(np.array([0]), route="paged")
        ks, vs, _ = mgr.get_context(np.array([0]), route="shortcut")
        sl = int(mgr.seq_lens(np.array([0]))[0])
        np.testing.assert_allclose(np.asarray(kp[:, :, :, :sl]),
                                   np.asarray(ks[:, :, :, :sl]))

    def test_route_prefers_paged_when_contiguous(self, rng):
        cache = make_cache()
        mgr = ShortcutKVManager(cache, seq_capacity=64,
                                frag_threshold=0.25)
        k, v = rand_kv(rng, 2, 1, 8, 2, 8)
        mgr.prefill(np.array([0]), k, v)
        mgr.pump()
        # contiguous fresh prefill: fragmentation 0 -> paged is fine
        assert mgr.route(np.array([0])) == "paged"

    def test_release_invalidates_view(self, rng):
        cache = make_cache()
        mgr = ShortcutKVManager(cache, seq_capacity=64)
        k, v = rand_kv(rng, 2, 1, 4, 2, 8)
        mgr.prefill(np.array([0]), k, v)
        mgr.pump()
        mgr.release(np.array([0]))
        assert not mgr.in_sync(np.array([0]))
        assert mgr.route(np.array([0])) == "paged"
