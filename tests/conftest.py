"""Shared test fixtures.

NOTE: no XLA_FLAGS device-count override here — smoke tests and benches
must see the real single CPU device (the 512-device override belongs to
launch/dryrun.py and the dedicated subprocess-based distributed tests).
"""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def unique_keys(rng, n, lo=1, hi=2**31):
    """Distinct uint32 keys, avoiding 0 and the EMPTY sentinel."""
    return rng.choice(np.arange(lo, hi, dtype=np.uint32), size=n,
                      replace=False)
