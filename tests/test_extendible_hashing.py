"""EH core: dict-oracle equivalence, structural invariants, hypothesis
property tests, and the shortcut-view equivalence (paper §2/§4)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, never hard-fail
from hypothesis import given, settings, strategies as st

from repro.core import extendible_hashing as eh

from conftest import unique_keys


def build(keys, vals, *, depth=8, slots=16, capacity=512):
    state = eh.eh_create(max_global_depth=depth, bucket_slots=slots,
                         capacity=capacity)
    return eh.eh_insert_many(state, jnp.asarray(keys), jnp.asarray(vals))


class TestLookup:
    def test_all_inserted_found(self, rng):
        keys = unique_keys(rng, 500)
        vals = np.arange(500, dtype=np.uint32)
        st_ = build(keys, vals)
        assert int(st_.dropped) == 0
        out = np.asarray(eh.eh_lookup_many(st_, jnp.asarray(keys)))
        np.testing.assert_array_equal(out, vals)

    def test_absent_keys_miss(self, rng):
        keys = unique_keys(rng, 300)
        st_ = build(keys[:200], np.arange(200, dtype=np.uint32))
        out = np.asarray(eh.eh_lookup_many(st_, jnp.asarray(keys[200:])))
        assert (out == 0xFFFFFFFF).all()

    def test_overwrite_updates_value(self, rng):
        keys = unique_keys(rng, 50)
        st_ = build(keys, np.arange(50, dtype=np.uint32))
        st_ = eh.eh_insert_many(st_, jnp.asarray(keys[:10]),
                                jnp.asarray(np.full(10, 999, np.uint32)))
        out = np.asarray(eh.eh_lookup_many(st_, jnp.asarray(keys[:10])))
        assert (out == 999).all()
        # no double-count
        assert int(eh.eh_num_entries(st_)) == 50


class TestInvariants:
    @pytest.mark.parametrize("n", [10, 100, 700])
    def test_structural_invariants(self, rng, n):
        keys = unique_keys(rng, n)
        st_ = build(keys, np.arange(n, dtype=np.uint32))
        report = eh.check_invariants(st_)
        assert report["ok"], report["errors"]

    def test_directory_doubles_progressively(self, rng):
        keys = unique_keys(rng, 600)
        state = eh.eh_create(max_global_depth=8, bucket_slots=16,
                             capacity=512)
        depths = []
        for i in range(0, 600, 100):
            state = eh.eh_insert_many(
                state, jnp.asarray(keys[i:i + 100]),
                jnp.asarray(np.arange(i, i + 100, dtype=np.uint32)))
            depths.append(int(state.global_depth))
        assert depths == sorted(depths)
        assert depths[-1] > 0


class TestShortcutView:
    """The composed view answers exactly like the traditional path."""

    @pytest.mark.parametrize("n", [50, 400])
    def test_view_equivalence(self, rng, n):
        keys = unique_keys(rng, n)
        st_ = build(keys, np.arange(n, dtype=np.uint32))
        g = int(st_.global_depth)
        vk, vv = eh.compose_shortcut(st_, 1 << g)
        probe = np.concatenate([keys, unique_keys(rng, 100, lo=2**31,
                                                  hi=2**32 - 2)])
        trad = eh.eh_lookup_many(st_, jnp.asarray(probe))
        shortcut = eh.shortcut_lookup_many(vk, vv, st_.global_depth,
                                           jnp.asarray(probe))
        np.testing.assert_array_equal(np.asarray(trad),
                                      np.asarray(shortcut))

    def test_remap_after_split_restores_equivalence(self, rng):
        """rewiring.remap_slots replay == fresh compose (update request)."""
        from repro.core import rewiring
        keys = unique_keys(rng, 400)
        st0 = build(keys[:200], np.arange(200, dtype=np.uint32))
        g0 = int(st0.global_depth)
        vk, vv = eh.compose_shortcut(st0, 1 << g0)
        st1 = eh.eh_insert_many(
            st0, jnp.asarray(keys[200:]),
            jnp.asarray(np.arange(200, 400, dtype=np.uint32)))
        if int(st1.global_depth) != g0:
            pytest.skip("directory doubled; update-request replay "
                        "does not apply (create request instead)")
        dir_np = np.asarray(st1.directory[: 1 << g0])
        slots = jnp.arange(1 << g0, dtype=jnp.int32)
        vk = rewiring.remap_slots(vk, st1.bucket_keys, slots,
                                  jnp.asarray(dir_np))
        vv = rewiring.remap_slots(vv, st1.bucket_vals, slots,
                                  jnp.asarray(dir_np))
        fresh_k, fresh_v = eh.compose_shortcut(st1, 1 << g0)
        np.testing.assert_array_equal(np.asarray(vk), np.asarray(fresh_k))
        np.testing.assert_array_equal(np.asarray(vv), np.asarray(fresh_v))


class TestHypothesis:
    @settings(deadline=None, max_examples=20)
    @given(st.lists(st.integers(min_value=1, max_value=2**31 - 1),
                    min_size=1, max_size=200, unique=True),
           st.integers(min_value=0, max_value=2**32 - 1))
    def test_matches_python_dict(self, keys, seed):
        """EH == dict for any insert sequence (values = index)."""
        keys = np.asarray(keys, np.uint32)
        vals = np.arange(len(keys), dtype=np.uint32)
        st_ = build(keys, vals, depth=10, slots=8, capacity=1024)
        oracle = dict(zip(keys.tolist(), vals.tolist()))
        out = np.asarray(eh.eh_lookup_many(st_, jnp.asarray(keys)))
        for k, got in zip(keys.tolist(), out.tolist()):
            assert got == oracle[k]
        report = eh.check_invariants(st_)
        assert report["ok"], report["errors"]

    @settings(deadline=None, max_examples=10)
    @given(st.lists(st.integers(min_value=1, max_value=2**31 - 1),
                    min_size=2, max_size=120, unique=True))
    def test_insertion_order_irrelevant(self, keys):
        keys = np.asarray(keys, np.uint32)
        vals = np.arange(len(keys), dtype=np.uint32)
        a = build(keys, vals, depth=10, slots=8, capacity=1024)
        perm = np.random.default_rng(0).permutation(len(keys))
        b = build(keys[perm], vals[perm], depth=10, slots=8, capacity=1024)
        probe = jnp.asarray(keys)
        np.testing.assert_array_equal(
            np.asarray(eh.eh_lookup_many(a, probe)),
            np.asarray(eh.eh_lookup_many(b, probe)))

    @settings(deadline=None, max_examples=10)
    @given(st.lists(st.integers(min_value=1, max_value=2**31 - 1),
                    min_size=1, max_size=150, unique=True))
    def test_fan_in_is_power_of_two_per_bucket(self, keys):
        """I2 (paper Fig 6): each bucket is referenced by exactly
        2^(g-l) contiguous slots."""
        keys = np.asarray(keys, np.uint32)
        st_ = build(keys, np.arange(len(keys), dtype=np.uint32),
                    depth=10, slots=8, capacity=1024)
        report = eh.check_invariants(st_)
        assert report["ok"], report["errors"]
