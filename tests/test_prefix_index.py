"""Prefix-cache index: EH-backed prefix matching for the serving layer."""
import numpy as np
import pytest

from repro.kvcache.prefix_index import PrefixCacheIndex


def test_exact_prefix_roundtrip(rng):
    idx = PrefixCacheIndex(block_size=4)
    toks = rng.integers(0, 50000, 32).tolist()
    idx.insert_prefix(toks, list(range(100, 108)))
    idx.pump()
    n, blocks = idx.match_prefix(toks)
    assert n == 32
    assert blocks == list(range(100, 108))


def test_partial_prefix_match(rng):
    idx = PrefixCacheIndex(block_size=4)
    shared = rng.integers(0, 50000, 16).tolist()
    idx.insert_prefix(shared + rng.integers(0, 50000, 16).tolist(),
                      list(range(8)))
    idx.pump()
    # a new request sharing only the first 16 tokens
    other = shared + rng.integers(50001, 60000, 16).tolist()
    n, blocks = idx.match_prefix(other)
    assert n == 16
    assert blocks == [0, 1, 2, 3]


def test_diverging_first_block_misses(rng):
    idx = PrefixCacheIndex(block_size=4)
    idx.insert_prefix(rng.integers(0, 50000, 16).tolist(), [0, 1, 2, 3])
    idx.pump()
    n, blocks = idx.match_prefix(rng.integers(50001, 60000, 16).tolist())
    assert n == 0 and blocks == []


def test_chain_prevents_middle_collision(rng):
    """Merkle chaining: identical block CONTENT at position i does not
    match unless the whole prefix [0, i] matches."""
    idx = PrefixCacheIndex(block_size=4)
    a = rng.integers(0, 50000, 8).tolist()
    idx.insert_prefix(a, [10, 11])
    idx.pump()
    # same second block, different first block
    b = rng.integers(50001, 60000, 4).tolist() + a[4:]
    n, blocks = idx.match_prefix(b)
    assert n == 0


def test_incomplete_blocks_ignored(rng):
    idx = PrefixCacheIndex(block_size=8)
    toks = rng.integers(0, 50000, 20).tolist()   # 2.5 blocks
    assert idx.insert_prefix(toks, [1, 2, 3]) == 2
    idx.pump()
    n, blocks = idx.match_prefix(toks)
    assert n == 16 and blocks == [1, 2]


def test_many_prefixes_shared_system_prompt(rng):
    """Realistic mix: one system prompt + many user suffixes."""
    idx = PrefixCacheIndex(block_size=4, capacity=8192)
    system = rng.integers(0, 50000, 24).tolist()
    idx.insert_prefix(system, list(range(6)))
    next_block = 6
    for _ in range(20):
        suffix = rng.integers(0, 50000, 8).tolist()
        full = system + suffix
        n, blocks = idx.match_prefix(full)
        assert n >= 24, "system prompt must always hit"
        idx.insert_prefix(full, blocks + [next_block, next_block + 1])
        next_block += 2
        idx.pump()
    s = idx.stats()
    assert s["hits"] == 20 and s["in_sync"]


def test_chain_keys_warning_free(rng):
    """FNV-1a uses masked Python-int arithmetic: intended mod-2^64
    wraparound, no numpy overflow RuntimeWarning."""
    import warnings
    idx = PrefixCacheIndex(block_size=4)
    toks = rng.integers(0, 2**31, 64).tolist()
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        keys = idx.chain_keys(toks)
    assert keys.size == 16
    assert (keys != 0).all() and (keys != 0xFFFFFFFF).all()


class TestPrefixShortcut:
    """The prefix -> block-table shortcut: third client of the shared
    maintenance runtime (one probe for a full-prefix hit)."""

    def test_full_hit_routes_through_shortcut(self, rng):
        idx = PrefixCacheIndex(block_size=4, chain_threshold=1.0)
        toks = rng.integers(0, 50000, 32).tolist()
        idx.insert_prefix(toks, list(range(100, 108)))
        assert not idx.prefix_mapper.in_sync(["__global__"])
        idx.pump()
        n, blocks = idx.match_prefix(toks)
        assert n == 32 and blocks == list(range(100, 108))
        s = idx.stats()
        assert s["prefix_in_sync"]
        assert s["prefix_routed_shortcut"] == 1

    def test_partial_match_falls_back_to_walk(self, rng):
        idx = PrefixCacheIndex(block_size=4, chain_threshold=1.0)
        shared = rng.integers(0, 50000, 16).tolist()
        idx.insert_prefix(shared + rng.integers(0, 50000, 16).tolist(),
                          list(range(8)))
        idx.pump()
        other = shared + rng.integers(50001, 60000, 16).tolist()
        n, blocks = idx.match_prefix(other)
        assert n == 16 and blocks == [0, 1, 2, 3]
        assert idx.stats()["prefix_routed_walk"] == 1

    def test_stale_view_routes_authoritative(self, rng):
        idx = PrefixCacheIndex(block_size=4, chain_threshold=1.0)
        toks = rng.integers(0, 50000, 16).tolist()
        idx.insert_prefix(toks, [0, 1, 2, 3])
        idx.index.pump()                    # per-block index in sync...
        # ...but the prefix view is NOT pumped: version gate must refuse
        n, blocks = idx.match_prefix(toks)
        assert n == 16 and blocks == [0, 1, 2, 3]
        assert idx.stats()["prefix_routed_shortcut"] == 0

    def test_growth_recreates_view(self, rng):
        idx = PrefixCacheIndex(block_size=4, table_log2=3,
                               chain_threshold=1.0)
        for i in range(12):                 # > 2^3 / 2 chains: forces growth
            toks = rng.integers(0, 50000, 8).tolist()
            idx.insert_prefix(toks, [2 * i, 2 * i + 1])
            idx.pump()
            n, blocks = idx.match_prefix(toks)
            assert n == 8 and blocks == [2 * i, 2 * i + 1]
        assert idx.prefix_mapper.stats.creates >= 2
        assert idx._view[3] > 3          # table grew past its initial log2

    def test_bulk_insert_grows_table_enough(self, rng):
        """One bulk insert may need more than a single doubling; no chain
        may be silently dropped from the rebuilt view."""
        idx = PrefixCacheIndex(block_size=4, table_log2=2,
                               chain_threshold=1.0)
        toks = rng.integers(0, 50000, 80).tolist()   # 20 chains at once
        idx.insert_prefix(toks, list(range(20)))
        idx.pump()
        assert (1 << idx._view[3]) >= 40             # 2x occupancy bound
        n, blocks = idx.match_prefix(toks)
        assert n == 80 and blocks == list(range(20))
        assert idx.stats()["prefix_routed_shortcut"] == 1
