"""Optimizer, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, never hard-fail
from hypothesis import given, settings, strategies as st

from repro.optim.adamw import adamw_init, adamw_update, global_norm
from repro.optim.compression import compress_int8, decompress_int8
from repro.optim.schedule import cosine_schedule, wsd_schedule


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    opt = adamw_init(params)
    target = jnp.asarray([1.0, 2.0, -1.0])
    for _ in range(300):
        grads = {"w": 2 * (params["w"] - target)}
        params, opt, _ = adamw_update(
            grads, opt, params, lr=jnp.float32(0.05), weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.asarray(target), atol=1e-2)


def test_factored_second_moment_shapes():
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((64,))}
    opt = adamw_init(params, factored=True)
    assert isinstance(opt.v["big"], dict)
    assert opt.v["big"]["vr"].shape == (256,)
    assert opt.v["big"]["vc"].shape == (512,)
    assert opt.v["small"].shape == (64,)  # too small to factor


def test_factored_still_converges():
    params = {"w": jnp.full((128, 128), 3.0)}
    opt = adamw_init(params, factored=True)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(
            grads, opt, params, lr=jnp.float32(0.05), weight_decay=0.0,
            factored=True)
    assert float(jnp.abs(params["w"]).max()) < 0.15


def test_grad_clipping():
    params = {"w": jnp.zeros((4,))}
    opt = adamw_init(params)
    grads = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = adamw_update(grads, opt, params,
                                 lr=jnp.float32(0.1), clip_norm=1.0)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)
    assert float(metrics["clip_scale"]) == pytest.approx(1 / 200.0,
                                                         rel=1e-4)


def test_wsd_schedule_shape():
    lr = [float(wsd_schedule(s, peak_lr=1.0, warmup_steps=10,
                             total_steps=100)) for s in range(101)]
    assert lr[0] == 0.0
    assert lr[10] == pytest.approx(1.0)
    assert lr[50] == pytest.approx(1.0)     # plateau
    assert lr[100] == pytest.approx(0.1)    # floor
    assert all(a >= b - 1e-6 for a, b in zip(lr[10:], lr[11:]))


def test_cosine_schedule_monotone_decay():
    lr = [float(cosine_schedule(s, peak_lr=1.0, warmup_steps=5,
                                total_steps=50)) for s in range(51)]
    assert lr[5] == pytest.approx(1.0)
    assert lr[50] == pytest.approx(0.1, rel=1e-3)


class TestCompression:
    def test_roundtrip_error_bounded(self, rng):
        g = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
        q, scale, err = compress_int8(g)
        deq = decompress_int8(q, scale, g.shape, jnp.float32)
        # per-block max/127 quantization error bound
        blocks = np.asarray(jnp.abs(g)).reshape(-1, 250 if False else 1)
        assert float(jnp.abs(deq - g).max()) <= \
            float(jnp.abs(g).max()) / 127.0 + 1e-6
        np.testing.assert_allclose(np.asarray(g - deq), np.asarray(err),
                                   atol=1e-6)

    @settings(deadline=None, max_examples=10)
    @given(st.integers(min_value=1, max_value=2000),
           st.integers(min_value=0, max_value=2**31 - 1))
    def test_error_feedback_drives_bias_to_zero(self, n, seed):
        """Property: with EF, the *accumulated* transmitted signal tracks
        the accumulated true gradient (bias does not grow)."""
        rng = np.random.default_rng(seed)
        g_true = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
        err = None
        sent_total = jnp.zeros_like(g_true)
        for _ in range(8):
            q, scale, err = compress_int8(g_true, err)
            sent_total = sent_total + decompress_int8(
                q, scale, g_true.shape, jnp.float32)
        # after T rounds of the SAME gradient, sum(sent) ~= T * g - err
        resid = np.abs(np.asarray(sent_total + err - 8 * g_true))
        assert resid.max() < 1e-4

    def test_all_zero_gradient(self):
        g = jnp.zeros((100,))
        q, scale, err = compress_int8(g)
        assert float(jnp.abs(decompress_int8(
            q, scale, g.shape, jnp.float32)).max()) == 0.0
        assert float(jnp.abs(err).max()) == 0.0


def test_global_norm():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(tree)) == pytest.approx(5.0)
