"""Shortcut-EH orchestration: version gating, async maintenance, fan-in
routing — the paper's §4.1 architecture."""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import extendible_hashing as eh
from repro.core.shortcut_eh import ShortcutEH

from conftest import unique_keys


def test_out_of_sync_until_pumped(rng):
    keys = unique_keys(rng, 200)
    sc = ShortcutEH(max_global_depth=8, bucket_slots=16, capacity=256)
    sc.insert(keys, np.arange(200, dtype=np.uint32))
    assert not sc.in_sync()          # maintenance is asynchronous
    assert not sc.use_shortcut()
    # lookups still correct via the traditional path
    out = np.asarray(sc.lookup(keys))
    np.testing.assert_array_equal(out, np.arange(200, dtype=np.uint32))
    assert sc.routed_traditional == 1 and sc.routed_shortcut == 0
    sc.pump()
    assert sc.in_sync()
    out = np.asarray(sc.lookup(keys))
    np.testing.assert_array_equal(out, np.arange(200, dtype=np.uint32))
    assert sc.routed_shortcut == 1


def test_versions_monotone_and_gate(rng):
    keys = unique_keys(rng, 300)
    sc = ShortcutEH(max_global_depth=8, bucket_slots=16, capacity=256)
    for i in range(0, 300, 100):
        sc.insert(keys[i:i + 100],
                  np.arange(i, i + 100, dtype=np.uint32))
        trad, short = sc.versions()
        assert short < trad
        sc.pump()
        trad, short = sc.versions()
        assert short == trad


def test_fan_in_routing(rng):
    """High fan-in (few buckets, wide directory) must route traditional
    (the TLB-thrashing lesson, §3.2)."""
    keys = unique_keys(rng, 40)
    sc = ShortcutEH(max_global_depth=8, bucket_slots=64, capacity=256,
                    fan_in_threshold=8.0)
    sc.insert(keys, np.arange(40, dtype=np.uint32))
    sc.pump()
    # force a wide directory by doubling manually: insert nothing more —
    # instead check the routing rule directly on both regimes
    if sc.avg_fan_in() <= 8.0:
        assert sc.use_shortcut()
    sc.fan_in_threshold = 0.5  # now even fan-in 1 is "too high"
    if sc.avg_fan_in() > 0.5:
        assert not sc.use_shortcut()
        out = np.asarray(sc.lookup(keys))
        np.testing.assert_array_equal(out, np.arange(40, dtype=np.uint32))


def test_async_mapper_thread(rng):
    keys = unique_keys(rng, 400)
    with ShortcutEH(max_global_depth=8, bucket_slots=16, capacity=512,
                    poll_interval=0.005, async_mapper=True) as sc:
        for i in range(0, 400, 100):
            sc.insert(keys[i:i + 100],
                      np.arange(i, i + 100, dtype=np.uint32))
        assert sc.wait_in_sync(timeout=30.0)
        out = np.asarray(sc.lookup(keys))
        np.testing.assert_array_equal(out, np.arange(400, dtype=np.uint32))
        assert sc.stats.creates >= 1
        assert sc.stats.populate_seconds >= 0.0


def test_create_collapses_stale_updates(rng):
    """A doubling enqueues a create request and pops outdated updates
    (paper §4.1); correctness must hold regardless of interleaving."""
    keys = unique_keys(rng, 600)
    sc = ShortcutEH(max_global_depth=9, bucket_slots=8, capacity=1024)
    for i in range(0, 600, 50):  # many small batches: splits + doublings
        sc.insert(keys[i:i + 50], np.arange(i, i + 50, dtype=np.uint32))
    sc.pump()
    assert sc.in_sync()
    out = np.asarray(sc.lookup(keys))
    np.testing.assert_array_equal(out, np.arange(600, dtype=np.uint32))
    report = eh.check_invariants(sc.state)
    assert report["ok"], report["errors"]
