"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step asserting output shapes + no NaNs, plus prefill->decode consistency
against the full forward — the strongest cache-correctness check.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get
from repro.models import model as M
from repro.models.ssm import SSMCache

B, S = 2, 64


def make_batch(cfg, key, with_labels=True):
    kt, ke, kl = jax.random.split(key, 3)
    batch = {}
    if cfg.input_mode == "tokens":
        toks = jax.random.randint(kt, (B, S + 1), 0, cfg.vocab_size)
        batch["tokens"] = toks[:, :-1]
        labels = toks[:, 1:]
    elif cfg.input_mode == "embeddings":
        batch["embeddings"] = jax.random.normal(
            ke, (B, S, cfg.d_model), jnp.float32) * 0.02
        labels = jax.random.randint(kl, (B, S), 0, cfg.vocab_size)
    else:  # prefix_embeddings
        toks = jax.random.randint(kt, (B, S + 1), 0, cfg.vocab_size)
        batch["tokens"] = toks[:, :-1]
        batch["prefix_embeddings"] = jax.random.normal(
            ke, (B, cfg.prefix_len, cfg.d_model), jnp.float32) * 0.02
        labels = toks[:, 1:]
    if with_labels:
        batch["labels"] = labels
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch):
    """One reduced-config forward+backward: finite loss, finite grads."""
    cfg = get(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key, jnp.float32)
    batch = make_batch(cfg, key)
    loss, grads = jax.value_and_grad(
        lambda p: M.train_forward(p, cfg, batch))(params)
    assert np.isfinite(float(loss)), arch
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)), arch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_shapes(arch):
    cfg = get(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key, jnp.float32)
    batch = make_batch(cfg, key, with_labels=False)
    logits, caches = M.prefill_forward(params, cfg, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    s_total = S + (cfg.prefix_len
                   if cfg.input_mode == "prefix_embeddings" else 0)
    if cfg.has_attention:
        L = cfg.num_layers
        assert caches.k.shape == (L, B, s_total, cfg.num_kv_heads,
                                  cfg.resolved_head_dim)
    if cfg.has_ssm:
        assert caches.ssm.state.shape[0] == cfg.num_layers


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_matches_full_forward(arch):
    """prefill(S) + decode_step == full forward at position S.

    Exercises rope positions, GQA, window masks, SSM state carry, and the
    decode cache layout for every architecture family."""
    cfg = get(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = M.init_params(cfg, key, jnp.float32)

    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    if cfg.input_mode == "embeddings":
        # decode embeds tokens via the embedding table, so feed the same
        # rows as "frame embeddings" to make the comparison exact
        emb = params["embed"][toks]
        full_batch = {"embeddings": emb}
        pre_batch = {"embeddings": emb[:, :S]}
    elif cfg.input_mode == "prefix_embeddings":
        prefix = jax.random.normal(
            key, (B, cfg.prefix_len, cfg.d_model), jnp.float32) * 0.02
        full_batch = {"tokens": toks, "prefix_embeddings": prefix}
        pre_batch = {"tokens": toks[:, :S], "prefix_embeddings": prefix}
    else:
        full_batch = {"tokens": toks}
        pre_batch = {"tokens": toks[:, :S]}

    # ground truth: last-position logits of the full (S+1) forward
    want, _ = M.prefill_forward(params, cfg, full_batch)

    # prefill S tokens, then decode token S
    _, caches = M.prefill_forward(params, cfg, pre_batch)
    prefix_len = cfg.prefix_len if cfg.input_mode == "prefix_embeddings" \
        else 0
    s_ctx = S + prefix_len
    pad = 16
    if cfg.has_attention:
        # decode ctx uses the attention-native (L,B,KV,S,hd) layout
        k = jnp.pad(caches.k.transpose(0, 1, 3, 2, 4),
                    ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(caches.v.transpose(0, 1, 3, 2, 4),
                    ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    else:
        k = v = ()
    ssm = caches.ssm if cfg.has_ssm else ()
    ctx = M.LayerCache(k=k, v=v, ssm=ssm)
    ctx_len = jnp.full((B,), s_ctx + 1, jnp.int32)
    got, new = M.decode_step(params, cfg, toks[:, S], ctx, ctx_len)

    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    if cfg.has_attention:
        assert new.k.shape == (cfg.num_layers, B, cfg.num_kv_heads,
                               cfg.resolved_head_dim)


def test_layer_runs_cover_all_layers():
    for arch in ASSIGNED:
        cfg = get(arch)
        runs = M.layer_runs(cfg)
        covered = []
        for start, length, kinds in runs:
            covered.extend(range(start, start + length))
            assert length % len(kinds) == 0
        assert covered == list(range(cfg.num_layers)), arch


def test_gemma2_local_global_pattern():
    cfg = get("gemma2_27b")
    kinds = M.layer_kinds(cfg)
    assert kinds[0] == "local" and kinds[1] == "global"
    assert all(kinds[i] == ("global" if i % 2 else "local")
               for i in range(len(kinds)))


def test_hymba_global_layers():
    cfg = get("hymba_1_5b")
    kinds = M.layer_kinds(cfg)
    assert [i for i, k in enumerate(kinds) if k == "global"] == [0, 15, 31]


def test_num_params_close_to_nameplate():
    """Analytic parameter counts should be in the right ballpark of the
    architecture nameplates (loose: vocab/head variants differ)."""
    expect = {"command_r_plus_104b": (80e9, 130e9),
              "gemma2_27b": (20e9, 36e9),
              "qwen3_4b": (3e9, 6e9),
              "internlm2_1_8b": (1.2e9, 2.5e9),
              "mamba2_370m": (0.25e9, 0.55e9),
              "arctic_480b": (380e9, 560e9)}
    for arch, (lo, hi) in expect.items():
        n = get(arch).num_params()
        assert lo < n < hi, (arch, n)
