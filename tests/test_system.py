"""End-to-end behaviour tests for the paper's system: the Fig-8-style
mixed workload (insert bursts -> shortcut goes out of sync -> catches up)
and a crash/restore training loop over the real substrate."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.shortcut_eh import ShortcutEH

from conftest import unique_keys


def test_mixed_workload_sync_cycle(rng):
    """Paper Fig. 8: bulk-load, then waves of 1% inserts + 99% lookups.
    After each insert burst the shortcut is stale (lookups still correct
    via the traditional path); after maintenance it serves again."""
    keys = unique_keys(rng, 3000)
    sc = ShortcutEH(max_global_depth=10, bucket_slots=16, capacity=2048)
    sc.insert(keys[:2400], np.arange(2400, dtype=np.uint32))  # bulk load
    sc.pump()
    assert sc.use_shortcut()

    inserted = 2400
    for wave in range(4):
        burst = keys[inserted:inserted + 150]
        sc.insert(burst, np.arange(inserted, inserted + 150,
                                   dtype=np.uint32))
        inserted += 150
        assert not sc.in_sync()            # stale immediately after burst
        lookups = keys[:inserted]
        out = np.asarray(sc.lookup(lookups))  # routed traditional
        np.testing.assert_array_equal(out, np.arange(inserted,
                                                     dtype=np.uint32))
        sc.pump()                          # mapper catches up
        assert sc.in_sync()
        out = np.asarray(sc.lookup(lookups))  # routed shortcut again
        np.testing.assert_array_equal(out, np.arange(inserted,
                                                     dtype=np.uint32))
    assert sc.routed_shortcut >= 4
    assert sc.routed_traditional >= 4


def test_train_crash_restore_bitwise(tmp_path):
    """Kill the training loop mid-run, restore from checkpoint, and land
    on bitwise-identical parameters vs an uninterrupted run (deterministic
    data pipeline + atomic checkpoints)."""
    from repro.checkpoint.checkpointer import Checkpointer, latest_step
    from repro.configs import get
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models import model as M
    from repro.optim.adamw import adamw_init
    from repro.optim.schedule import wsd_schedule
    from repro.runtime.train import make_train_step

    cfg = get("internlm2_1_8b").reduced()
    pipe = SyntheticLM(cfg, DataConfig(seq_len=32, global_batch=4))
    step_fn = jax.jit(make_train_step(
        cfg, lr_fn=lambda s: wsd_schedule(s, peak_lr=1e-2,
                                          warmup_steps=2,
                                          total_steps=100),
        remat=False).fn)

    def fresh():
        params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        return params, adamw_init(params)

    # uninterrupted 6-step run
    params, opt = fresh()
    for i in range(6):
        params, opt, _ = step_fn(params, opt, pipe.batch(i))
    want = jax.tree.leaves(params)[0]

    # interrupted run: checkpoint at 3, "crash", restore, resume
    ck = Checkpointer(str(tmp_path))
    params, opt = fresh()
    for i in range(3):
        params, opt, _ = step_fn(params, opt, pipe.batch(i))
    ck.save(3, {"params": params, "opt": opt})
    del params, opt                         # the crash

    step = latest_step(str(tmp_path))
    assert step == 3
    p0, o0 = fresh()
    restored = ck.restore(step, {"params": p0, "opt": o0})
    params, opt = restored["params"], restored["opt"]
    for i in range(step, 6):
        params, opt, _ = step_fn(params, opt, pipe.batch(i))
    got = jax.tree.leaves(params)[0]
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
