"""Fault-tolerance runtime: heartbeats, stragglers, crash-loop restart."""
import time

import pytest

from repro.runtime.watchdog import (Heartbeat, StragglerMonitor, Watchdog,
                                    run_restartable)


def test_watchdog_fires_on_stale_heartbeat():
    hb = [Heartbeat(0), Heartbeat(1)]
    dead: list = []
    with Watchdog(hb, deadline_s=0.15, on_dead=dead.extend,
                  poll_s=0.02):
        t0 = time.monotonic()
        while time.monotonic() - t0 < 0.5:
            hb[0].beat(1)          # worker 0 stays alive
            time.sleep(0.02)
    assert dead == [1]


def test_watchdog_quiet_when_all_beat():
    hb = [Heartbeat(0)]
    dead: list = []
    with Watchdog(hb, deadline_s=0.2, on_dead=dead.extend, poll_s=0.02):
        for _ in range(10):
            hb[0].beat(1)
            time.sleep(0.02)
    assert dead == []


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=2.0)
    for _ in range(10):
        assert not mon.record(0.1)
    assert mon.record(1.0)          # 10x the median
    assert mon.flagged == 1
    assert mon.median() == pytest.approx(0.1)


def test_run_restartable_recovers():
    state = {"restores": 0, "attempts": 0}

    def restore():
        state["restores"] += 1
        return state["restores"] * 10   # checkpointed step advances

    def body(start):
        state["attempts"] += 1
        if state["attempts"] < 3:
            raise RuntimeError("simulated node failure")
        return start + 5

    final = run_restartable(body, restore=restore, max_restarts=3)
    assert final == 35                  # third restore -> start 30 -> +5
    assert state["restores"] == 3


def test_run_restartable_exhausts():
    def body(start):
        raise RuntimeError("hard failure")

    with pytest.raises(RuntimeError):
        run_restartable(body, restore=lambda: 0, max_restarts=2)
