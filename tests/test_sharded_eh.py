"""Sharded shortcut runtime (core/sharded_eh + runtime/shard_group):
oracle parity across shard counts, per-shard invariants, shard-local
maintenance, and MapperGroup independence."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import extendible_hashing as eh
from repro.core.sharded_eh import (ShardedShortcutEH, partition_by_shard,
                                   shard_of_keys)
from repro.core.shortcut_eh import ShortcutEH
from repro.runtime.mapper import (GLOBAL_VIEW, FanInRouting,
                                  ShortcutMapper)
from repro.runtime.shard_group import MapperGroup

from conftest import unique_keys


def _mixed_trace(rng, n=1200):
    """Mixed insert/probe trace: bursts of inserts interleaved with
    probes of everything seen so far plus guaranteed misses."""
    keys = unique_keys(rng, n)
    vals = np.arange(n, dtype=np.uint32)
    misses = unique_keys(rng, 200, lo=2**31, hi=2**32 - 2)
    return keys, vals, misses


def _keys_for_shard(rng, shard, shard_bits, n):
    """Rejection-sample keys whose hash routes them to ``shard``."""
    out = []
    while len(out) < n:
        cand = unique_keys(rng, 4 * n)
        cand = cand[shard_of_keys(cand, shard_bits) == shard]
        out.extend(cand.tolist())
    return np.unique(np.asarray(out[:n], np.uint32))


class TestOracleParity:
    @pytest.mark.parametrize("num_shards", [1, 2, 8])
    def test_matches_dict_and_flat_index(self, rng, num_shards):
        """Same trace through a dict oracle, a flat ShortcutEH, and the
        sharded index: results bit-for-bit identical at every step."""
        keys, vals, misses = _mixed_trace(rng)
        oracle = {}
        flat = ShortcutEH(12, 8, 2048)
        sharded = ShardedShortcutEH(12, 8, 2048, num_shards=num_shards)
        step = 300
        for i in range(0, len(keys), step):
            kb, vb = keys[i:i + step], vals[i:i + step]
            oracle.update(zip(kb.tolist(), vb.tolist()))
            flat.insert(kb, vb)
            sharded.insert(kb, vb)
            # probe BEFORE maintenance (stale views): traditional routes
            probe = np.concatenate([keys[:i + step], misses])
            got = np.asarray(sharded.lookup(probe))
            want = np.asarray(flat.lookup(probe))
            np.testing.assert_array_equal(got, want)
            flat.pump()
            sharded.pump()
            assert sharded.in_sync()
            # probe AFTER maintenance (shortcut-eligible routes)
            got = np.asarray(sharded.lookup(probe))
            np.testing.assert_array_equal(got, np.asarray(
                flat.lookup(probe)))
            expect = np.asarray(
                [oracle.get(int(k), 0xFFFFFFFF) for k in probe], np.uint32)
            np.testing.assert_array_equal(got, expect)
        flat.close()
        sharded.close()

    @pytest.mark.parametrize("num_shards", [2, 8])
    def test_batched_kernel_path_matches(self, rng, num_shards):
        keys, vals, misses = _mixed_trace(rng, n=900)
        sharded = ShardedShortcutEH(12, 8, 2048, num_shards=num_shards)
        sharded.insert(keys, vals)
        probe = np.concatenate([keys, misses])
        # stale: traditional fused kernel resolves all shards
        got = np.asarray(sharded.lookup_batched(probe))
        np.testing.assert_array_equal(got, np.asarray(
            sharded.lookup(probe)))
        sharded.pump()
        # in sync: shortcut fused kernel (when views are shape-uniform)
        got = np.asarray(sharded.lookup_batched(probe))
        expect = np.concatenate([vals, np.full(len(misses), 0xFFFFFFFF,
                                               np.uint32)])
        np.testing.assert_array_equal(got, expect)
        sharded.close()


class TestShardLocality:
    @pytest.mark.parametrize("num_shards", [2, 8])
    def test_per_shard_invariants(self, rng, num_shards):
        keys, vals, _ = _mixed_trace(rng)
        with ShardedShortcutEH(12, 8, 2048,
                               num_shards=num_shards) as sharded:
            for i in range(0, len(keys), 150):  # small batches: splits
                sharded.insert(keys[i:i + 150], vals[i:i + 150])
            sharded.pump()
            report = sharded.check_invariants()   # I1-I5 + S1 per shard
            assert report["ok"], report["errors"]
            assert len(report["shards"]) == num_shards
            total = sharded.num_entries()
            assert total == len(keys)

    def test_maintenance_confined_to_owning_shard(self, rng):
        """Inserts routed to shard 0 must not touch shard 1's versions,
        queue, or MaintenanceStats (the paper's §5 shootdown cost,
        confined)."""
        shard_bits = 1
        k0 = _keys_for_shard(rng, 0, shard_bits, 300)
        with ShardedShortcutEH(10, 8, 1024, num_shards=2) as sharded:
            sharded.insert(k0, np.arange(len(k0), dtype=np.uint32))
            s0, s1 = sharded.per_shard_stats()
            m0, m1 = sharded.group[0], sharded.group[1]
            assert m0.trad_version(GLOBAL_VIEW) > 0
            assert m1.trad_version(GLOBAL_VIEW) == 0   # never bumped
            sharded.pump()
            assert (s0.creates + s0.updates) >= 1
            assert s1.creates == s1.updates == 0       # no replay at all
            assert s1.slots_remapped == 0
            # lookups for shard-0 keys are correct and shard 1 untouched
            out = np.asarray(sharded.lookup(k0))
            np.testing.assert_array_equal(
                out, np.arange(len(k0), dtype=np.uint32))


class _Toy:
    """Minimal per-shard runtime client (mirrors test_mapper.ToyClient)."""

    def __init__(self):
        self.data = {}
        self.view = {}
        self.mapper = ShortcutMapper(
            replay_create=lambda snap, reqs: self.view.update(snap),
            replay_update=self._replay_update,
            snapshot=lambda: dict(self.data),
            view_arrays=tuple, routing=FanInRouting(8.0))

    def _replay_update(self, snap, requests):
        for r in requests:
            k, v = r.payload
            self.view[k] = v

    def put(self, key, val, kind="update"):
        with self.mapper.lock:
            self.data[key] = val
            versions = self.mapper.record([GLOBAL_VIEW])
        if kind == "create":
            self.mapper.submit_create([GLOBAL_VIEW], versions)
        else:
            self.mapper.submit_update([GLOBAL_VIEW], versions,
                                      payload=(key, val))


class TestMapperGroup:
    def test_create_does_not_collapse_other_shards_updates(self):
        """The collapse scope is one shard: a create on shard 0 leaves
        shard 1's pending updates alone, and shard 0's staleness does
        not gate shard 1's reads."""
        toys = [_Toy(), _Toy()]
        group = MapperGroup([t.mapper for t in toys],
                            router=lambda k: int(k) % 2)
        toys[1].put(3, "b")                      # pending update, shard 1
        toys[0].put(0, "a", kind="create")       # create, shard 0
        assert group[0].stats.collapsed == 0
        assert group[1].stats.collapsed == 0     # NOT collapsed cross-shard
        # shard 1 can catch up independently of shard 0
        group[1].pump()
        assert group.in_sync({1: [GLOBAL_VIEW]})
        assert not group.in_sync({0: [GLOBAL_VIEW]})
        assert not group.in_sync()               # group-wide gate still down
        assert toys[1].view == {3: "b"}
        group.pump()
        assert group.in_sync()
        assert toys[0].view == {0: "a"}

    def test_aggregated_stats_and_route_counts(self):
        toys = [_Toy(), _Toy(), _Toy()]
        group = MapperGroup([t.mapper for t in toys],
                            router=lambda k: int(k) % 3)
        for i in range(6):
            toys[i % 3].put(i, i)
        assert group.pump() == 6
        agg = group.stats
        assert agg.updates == sum(t.mapper.stats.updates for t in toys) >= 3
        group.count_route(True)                # batch-level: group counter
        group.count_route(False, shard=2)      # shard-attributed
        assert group.routed_shortcut == 1 and group.routed_fallback == 1
        assert group[2].routed_fallback == 1
        # a batch-level decision must NOT skew any member's stats
        # (the old default credited every multi-shard batch to shard 0)
        assert all(m.routed_shortcut == 0 for m in group)

    def test_router_bounds_checked(self):
        group = MapperGroup([_Toy().mapper], router=lambda k: 5)
        with pytest.raises(IndexError):
            group.route("anything")
        with pytest.raises(ValueError):
            MapperGroup([])

    def test_gate_requires_every_involved_policy(self):
        toys = [_Toy(), _Toy()]
        group = MapperGroup([t.mapper for t in toys])
        toys[0].put(0, "a")
        toys[1].put(1, "b")
        group.pump()
        group[1].threshold = 0.5       # shard 1's policy now refuses 1.0
        assert group.gate(1.0, {0: [GLOBAL_VIEW]})
        assert not group.gate(1.0, {0: [GLOBAL_VIEW], 1: [GLOBAL_VIEW]})


class TestPartition:
    def test_partition_roundtrip(self, rng):
        keys = unique_keys(rng, 500)
        sid = shard_of_keys(keys, 2)
        cap = int(np.bincount(sid, minlength=4).max())
        padded, counts, order, rank = partition_by_shard(keys, sid, 4, cap)
        assert counts.sum() == keys.size
        # every key sits in its shard's row, and scatter-back restores it
        out = np.empty(keys.size, keys.dtype)
        out[order] = padded[sid[order], rank]
        np.testing.assert_array_equal(out, keys)
        for s in range(4):
            row = padded[s, :counts[s]]
            assert (shard_of_keys(row, 2) == s).all()

    def test_shard_of_matches_directory_msb(self, rng):
        """Shard routing IS the directory's MSB rule: shard bits are the
        top bits of hash_dir, so the shard partition refines the flat
        directory partition."""
        keys = unique_keys(rng, 256)
        h = np.asarray(eh.hash_dir(jnp.asarray(keys)))
        np.testing.assert_array_equal(
            shard_of_keys(keys, 3), (h >> np.uint32(29)).astype(np.int64))


class TestShardedKV:
    def test_sharded_manager_matches_paged(self, rng):
        """num_shards=2 KV manager: parity with the paged path and
        shard-independent sync (a prefill on shard-0 seqs does not gate
        shard-1 seqs)."""
        from repro.kvcache import paged_cache as pc
        from repro.kvcache.shortcut_cache import ShortcutKVManager
        L, nb, bs, KV, hd, max_seqs, cap = 2, 32, 4, 2, 8, 4, 32
        cache = pc.cache_create(L, nb, bs, KV, hd, max_seqs, cap // bs,
                                dtype=jnp.float32)
        mgr = ShortcutKVManager(cache, seq_capacity=cap, num_shards=2)
        T = 12
        k = jnp.asarray(rng.normal(size=(L, 2, T, KV, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(L, 2, T, KV, hd)), jnp.float32)
        mgr.prefill(np.asarray([1, 3]), k, v)      # both shard 1 (odd)
        mgr.pump()
        assert mgr.in_sync(np.asarray([1, 3]))
        shard1_creates = mgr.group[1].stats.creates
        assert shard1_creates >= 1
        mgr.prefill(np.asarray([0, 2]), k, v)      # both shard 0 (even)
        assert not mgr.in_sync(np.asarray([0, 2]))   # shard 0 stale...
        assert mgr.in_sync(np.asarray([1, 3]))       # ...shard 1 not gated
        assert mgr.group[0].trad_version(1) == 0     # seq 1 not on shard 0
        mgr.pump()
        assert mgr.in_sync(np.asarray([0, 2]))
        # parity of both access paths after sync
        ks, vs, route = mgr.get_context(np.asarray([0, 2]),
                                        route="shortcut")
        kp, vp, _ = mgr.get_context(np.asarray([0, 2]), route="paged")
        np.testing.assert_allclose(np.asarray(ks)[:, :, :, :T],
                                   np.asarray(kp)[:, :, :, :T],
                                   rtol=0, atol=0)
        # shard-0 maintenance stayed on shard 0's mapper: shard 1's
        # replay count did not move when shard 0 caught up
        assert mgr.group[0].stats.creates >= 1
        assert mgr.group[1].stats.creates == shard1_creates
        mgr.close()
