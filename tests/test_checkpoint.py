"""Checkpointer: atomicity, async writer, retention GC, elastic restore."""
import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer, latest_step
from repro.optim.adamw import AdamWState


def make_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)),
                   "b": jnp.zeros((4,))},
        "opt": AdamWState(step=jnp.int32(7),
                          m={"w": jnp.ones((8, 4)), "b": jnp.zeros((4,))},
                          v={"w": jnp.ones((8, 4)), "b": jnp.zeros((4,))}),
    }


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = make_tree()
    ck.save(3, tree)
    assert latest_step(str(tmp_path)) == 3
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        tree)
    out = ck.restore(3, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert isinstance(out["opt"], AdamWState)  # NamedTuple reconstructed


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = make_tree()
    ck.save_async(5, tree)
    ck.wait()
    assert latest_step(str(tmp_path)) == 5
    out = ck.restore(5, tree)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_crashed_tmp_dir_ignored_and_gcd(tmp_path):
    ck = Checkpointer(str(tmp_path))
    # simulate a crash mid-write: leftover .tmp with partial contents
    crash = tmp_path / "step_9.tmp"
    crash.mkdir()
    (crash / "arr_00000.npy").write_bytes(b"garbage")
    assert latest_step(str(tmp_path)) is None
    ck.save(10, make_tree())
    assert latest_step(str(tmp_path)) == 10
    assert not crash.exists()          # GC'd by the successful save


def test_retention_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        ck.save(s, make_tree(s))
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]


def test_restore_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError, match="shape"):
        ck.restore(1, {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)})


def test_restore_missing_array_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.zeros((4,))})
    with pytest.raises(KeyError):
        ck.restore(1, {"w": jax.ShapeDtypeStruct((4,), jnp.float32),
                       "extra": jax.ShapeDtypeStruct((2,), jnp.float32)})


def test_elastic_restore_with_shardings(tmp_path):
    """Arrays restore onto an explicit (single-device here) sharding —
    the mesh-A-save / mesh-B-restore path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(2, tree)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = {"w": NamedSharding(mesh, P())}
    out = ck.restore(2, tree, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    assert out["w"].sharding == shardings["w"]


def test_manifest_is_complete(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, make_tree())
    with open(tmp_path / "step_1" / "MANIFEST.json") as f:
        manifest = json.load(f)
    assert manifest["step"] == 1
    files = set(os.listdir(tmp_path / "step_1")) - {"MANIFEST.json"}
    assert files == {m["file"] for m in manifest["arrays"].values()}
