"""Serving runtime integration: prefill -> serve_step greedy decode is
identical between the shortcut path, the paged path, and the full-forward
ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.kvcache import paged_cache as pc
from repro.models import model as M
from repro.runtime.serve import (DecodeState, decode_state_init,
                                 make_paged_serve_step, make_prefill_step,
                                 make_serve_step, merge_decode_states,
                                 shard_decode_state)

B, S, S_CAP = 2, 32, 64


@pytest.fixture(scope="module")
def setup():
    cfg = get("qwen3_4b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    return cfg, params, toks


def greedy_ground_truth(cfg, params, toks, steps):
    """Decode by re-running the full forward each step (no cache)."""
    cur = toks
    out = []
    for _ in range(steps):
        logits, _ = M.prefill_forward(params, cfg, {"tokens": cur})
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(nxt)
        cur = jnp.concatenate([cur, nxt[:, None]], axis=1)
    return jnp.stack(out, axis=1)


def test_shortcut_serve_matches_ground_truth(setup):
    cfg, params, toks = setup
    steps = 4
    want = greedy_ground_truth(cfg, params, toks, steps)

    prefill = make_prefill_step(cfg, s_cap=S_CAP, dtype=jnp.float32)
    serve = jax.jit(make_serve_step(cfg))
    logits, state = prefill(params, {"tokens": toks})
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    got = [tok]
    for _ in range(steps - 1):
        tok, state = serve(params, state, tok)
        got.append(tok)
    got = jnp.stack(got, axis=1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_serve_matches_ground_truth(setup):
    cfg, params, toks = setup
    steps = 4
    want = greedy_ground_truth(cfg, params, toks, steps)

    bs = 8
    cache = pc.cache_create(
        cfg.num_layers, num_blocks=32, block_size=bs,
        kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
        max_seqs=B, max_blocks_per_seq=S_CAP // bs, dtype=jnp.float32)
    # prefill via the model, write into the paged pool
    logits, caches = M.prefill_forward(params, cfg, {"tokens": toks})
    cache = pc.write_prefill(cache, jnp.arange(B), caches.k, caches.v)
    serve = jax.jit(make_paged_serve_step(cfg))
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    seq_ids = jnp.arange(B, dtype=jnp.int32)
    got = [tok]
    for _ in range(steps - 1):
        tok, cache = serve(params, cache, tok, seq_ids)
        got.append(tok)
    got = jnp.stack(got, axis=1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("arch", ["mamba2_370m", "hymba_1_5b",
                                  "gemma2_27b"])
def test_stateful_families_serve(arch):
    """SSM / hybrid / local-global archs run the serve loop and agree
    with the no-cache ground truth."""
    cfg = get(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(2), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                              cfg.vocab_size)
    steps = 3
    want = greedy_ground_truth(cfg, params, toks, steps)
    prefill = make_prefill_step(cfg, s_cap=S_CAP, dtype=jnp.float32)
    serve = jax.jit(make_serve_step(cfg))
    logits, state = prefill(params, {"tokens": toks})
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    got = [tok]
    for _ in range(steps - 1):
        tok, state = serve(params, state, tok)
        got.append(tok)
    got = jnp.stack(got, axis=1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_decode_state_init_shapes():
    cfg = get("hymba_1_5b").reduced()
    st = decode_state_init(cfg, batch=3, s_cap=16, dtype=jnp.float32)
    assert st.view_k.shape[0] == cfg.num_layers
    assert st.ssm_state.shape[1] == 3
    assert st.ctx_len.shape == (3,)


def _mark_rows(x, axis):
    """Add the batch-row index to every element so interleaving mistakes
    are detectable."""
    if isinstance(x, tuple):
        return ()
    n = x.shape[axis]
    shape = [1] * x.ndim
    shape[axis] = n
    return x + jnp.arange(n, dtype=x.dtype).reshape(shape)


@pytest.mark.parametrize("num_shards", [1, 2, 3])
def test_shard_merge_decode_state_roundtrip(num_shards):
    # hymba: attention + ssm -> every DecodeState member exercised
    cfg = get("hymba_1_5b").reduced()
    B = 5
    st = decode_state_init(cfg, batch=B, s_cap=16, dtype=jnp.float32)
    st = DecodeState(view_k=_mark_rows(st.view_k, 1),
                     view_v=_mark_rows(st.view_v, 1),
                     ssm_conv=_mark_rows(st.ssm_conv, 1),
                     ssm_state=_mark_rows(st.ssm_state, 1),
                     ctx_len=_mark_rows(st.ctx_len, 0))
    parts = shard_decode_state(st, num_shards)
    assert len(parts) == num_shards
    # shard s owns rows s, s+N, ... (the ShortcutKVManager partition)
    for s, p in enumerate(parts):
        np.testing.assert_array_equal(
            np.asarray(p.ctx_len), np.asarray(st.ctx_len[s::num_shards]))
        assert p.view_k.shape[1] == len(range(s, B, num_shards))
    merged = merge_decode_states(parts)
    for got, want in zip(merged, st):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sharded_decode_matches_whole_batch(setup):
    """N independent per-shard decode loops (each owning its own view
    tensors — no shared state, no view lock) produce the same tokens and
    merged state as the whole-batch loop."""
    cfg, params, toks = setup
    steps, N = 3, 2
    prefill = make_prefill_step(cfg, s_cap=S_CAP, dtype=jnp.float32)
    serve = jax.jit(make_serve_step(cfg))
    logits, state = prefill(params, {"tokens": toks})
    tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    whole_toks, wtok, wstate = [], tok0, state
    for _ in range(steps):
        wtok, wstate = serve(params, wstate, wtok)
        whole_toks.append(np.asarray(wtok))

    parts = shard_decode_state(state, N)
    shard_toks = [[] for _ in range(N)]
    for s in range(N):
        t, stt = tok0[s::N], parts[s]
        for _ in range(steps):
            t, stt = serve(params, stt, t)
            shard_toks[s].append(np.asarray(t))
        parts[s] = stt

    for step in range(steps):
        merged_tok = np.empty(B, np.int32)
        for s in range(N):
            merged_tok[s::N] = shard_toks[s][step]
        np.testing.assert_array_equal(whole_toks[step], merged_tok)
    merged = merge_decode_states(parts)
    np.testing.assert_array_equal(np.asarray(merged.ctx_len),
                                  np.asarray(wstate.ctx_len))
    np.testing.assert_allclose(np.asarray(merged.view_k),
                               np.asarray(wstate.view_k), rtol=1e-6)
