"""The paper's §4.2 baselines (HT / HTI / CH) against a dict oracle."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, never hard-fail
from hypothesis import given, settings, strategies as st

from repro.core import baselines as bl

from conftest import unique_keys

MISS = 0xFFFFFFFF


class TestHT:
    def test_roundtrip_with_rehash(self, rng):
        keys = unique_keys(rng, 400)
        vals = np.arange(400, dtype=np.uint32)
        state = bl.ht_create(max_size_log2=12, initial_size_log2=4)
        state = bl.ht_insert_many(state, jnp.asarray(keys),
                                  jnp.asarray(vals))
        assert int(state.dropped) == 0
        assert int(state.size_log2) > 4  # rehashed at least once
        out = np.asarray(bl.ht_lookup_many(state, jnp.asarray(keys)))
        np.testing.assert_array_equal(out, vals)

    def test_misses(self, rng):
        keys = unique_keys(rng, 100)
        state = bl.ht_create(max_size_log2=12)
        state = bl.ht_insert_many(state, jnp.asarray(keys[:50]),
                                  jnp.asarray(np.arange(50, dtype=np.uint32)))
        out = np.asarray(bl.ht_lookup_many(state, jnp.asarray(keys[50:])))
        assert (out == MISS).all()


class TestHTI:
    def test_roundtrip_through_migration(self, rng):
        keys = unique_keys(rng, 600)
        vals = np.arange(600, dtype=np.uint32)
        state = bl.hti_create(max_size_log2=13, initial_size_log2=4)
        # insert in small batches so lookups hit mid-migration states
        for i in range(0, 600, 60):
            state = bl.hti_insert_many(
                state, jnp.asarray(keys[i:i + 60]),
                jnp.asarray(vals[i:i + 60]), migrate_batch=16)
            out = np.asarray(bl.hti_lookup_many(
                state, jnp.asarray(keys[:i + 60])))
            np.testing.assert_array_equal(out, vals[:i + 60])
        assert int(state.dropped) == 0

    def test_migration_completes(self, rng):
        keys = unique_keys(rng, 300)
        state = bl.hti_create(max_size_log2=12, initial_size_log2=4)
        state = bl.hti_insert_many(state, jnp.asarray(keys),
                                   jnp.asarray(np.arange(300, dtype=np.uint32)),
                                   migrate_batch=64)
        # keep inserting nothing; drive migration with repeat lookups?
        # migration advances on insert; a drained state has old_count==0
        # after enough batches:
        state = bl.hti_insert_many(state, jnp.asarray(keys[:1]),
                                   jnp.asarray(np.zeros(1, np.uint32)),
                                   migrate_batch=1 << 12)
        assert not bool(state.migrating)
        assert int(state.old_count) == 0


class TestCH:
    def test_roundtrip_with_chains(self, rng):
        keys = unique_keys(rng, 500)
        vals = np.arange(500, dtype=np.uint32)
        # tiny table -> long chains
        state = bl.ch_create(table_log2=4, capacity=256, bucket_slots=8)
        state = bl.ch_insert_many(state, jnp.asarray(keys),
                                  jnp.asarray(vals))
        assert int(state.dropped) == 0
        out = np.asarray(bl.ch_lookup_many(state, jnp.asarray(keys)))
        np.testing.assert_array_equal(out, vals)
        assert int(state.num_buckets) > 16  # chains actually formed


class TestCrossOracle:
    @settings(deadline=None, max_examples=10)
    @given(st.lists(st.integers(min_value=1, max_value=2**31 - 1),
                    min_size=1, max_size=150, unique=True))
    def test_all_tables_agree(self, keys):
        """HT, HTI, CH, EH answer identically for any key set."""
        from repro.core import extendible_hashing as eh
        keys = np.asarray(keys, np.uint32)
        vals = np.arange(len(keys), dtype=np.uint32)
        kj, vj = jnp.asarray(keys), jnp.asarray(vals)
        ht = bl.ht_insert_many(bl.ht_create(12), kj, vj)
        hti = bl.hti_insert_many(bl.hti_create(12), kj, vj)
        ch = bl.ch_insert_many(bl.ch_create(6, 512), kj, vj)
        ehs = eh.eh_insert_many(
            eh.eh_create(10, 8, 1024), kj, vj)
        a = np.asarray(bl.ht_lookup_many(ht, kj))
        b = np.asarray(bl.hti_lookup_many(hti, kj))
        c = np.asarray(bl.ch_lookup_many(ch, kj))
        d = np.asarray(eh.eh_lookup_many(ehs, kj))
        np.testing.assert_array_equal(a, vals)
        np.testing.assert_array_equal(b, vals)
        np.testing.assert_array_equal(c, vals)
        np.testing.assert_array_equal(d, vals)
