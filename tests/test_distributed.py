"""Sharding rules (pure logic — no devices needed) + a subprocess-based
multi-device integration test (8 fake CPU devices)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (default_rules, logical_spec,
                                        param_names)


@pytest.fixture(scope="module")
def mesh():
    # a 1x1 named mesh is enough to unit-test spec RESOLUTION logic --
    # divisibility is checked against axis sizes, so use a fake spec of
    # the production mesh instead:
    return FakeMesh({"data": 16, "model": 16})


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


class TestLogicalSpec:
    def test_divisible_dims_shard(self, mesh):
        spec = logical_spec((256, 4096), ("batch", None), mesh)
        assert spec == P("data")

    def test_indivisible_dim_replicates(self, mesh):
        # 8 kv heads cannot split over 16-way model axis
        spec = logical_spec((8,), ("kv_heads",), mesh)
        assert spec == P()

    def test_fallback_candidate_used(self, mesh):
        # expert: model first, then data; 60 divides neither -> replicate
        assert logical_spec((60,), ("expert",), mesh) == P()
        # 32 divides both; model has priority
        assert logical_spec((32,), ("expert",), mesh) == P("model")

    def test_axis_consumed_once(self, mesh):
        # both vocab and heads want "model": first (by priority) wins
        spec = logical_spec((32000, 64), ("vocab", "heads"), mesh)
        assert spec == P("model")

    def test_ctx_yields_to_kv_heads(self, mesh):
        # kv_heads=16 divisible: ctx must NOT steal the model axis
        spec = logical_spec((4, 128, 32768, 16, 128),
                            ("layer", "batch", "ctx", "kv_heads",
                             "head_dim"), mesh)
        assert spec == P(None, "data", None, "model")

    def test_ctx_takes_model_when_kv_cannot(self, mesh):
        spec = logical_spec((4, 128, 32768, 8, 128),
                            ("layer", "batch", "ctx", "kv_heads",
                             "head_dim"), mesh)
        assert spec == P(None, "data", "model")

    def test_multi_pod_batch_tuple(self):
        mesh3 = FakeMesh({"pod": 2, "data": 16, "model": 16})
        spec = logical_spec((256, 4096), ("batch", None), mesh3)
        assert spec == P(("pod", "data"))
        # batch=1 cannot shard at all
        assert logical_spec((1,), ("batch",), mesh3) == P()


class TestEHSpecs:
    """Sharded-EH dims place via the same divisibility-aware rules."""

    def test_stacked_lookup_operands(self, mesh):
        # 16 shards over the data axis; directory/buckets over model;
        # the probed bucket row (eh_slots) must stay contiguous
        assert logical_spec((16, 1 << 14), ("eh_shard", "eh_dir"),
                            mesh) == P("data", "model")
        assert logical_spec((16, 4096, 64),
                            ("eh_shard", "eh_buckets", "eh_slots"),
                            mesh) == P("data", "model")

    def test_indivisible_shards_replicate(self, mesh):
        # 2 shards cannot split a 16-way data axis -> replicate the
        # shard dim, directory still lands on model
        assert logical_spec((2, 1 << 14), ("eh_shard", "eh_dir"),
                            mesh) == P(None, "model")

    def test_sharded_eh_specs_helper(self):
        # a real (1x1) mesh: every dim divides, so names resolve in
        # priority order — exercises the NamedSharding construction
        import numpy as np
        from jax.sharding import Mesh
        from repro.distributed.sharding import sharded_eh_specs
        real = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))

        class Shaped:
            def __init__(self, shape):
                self.shape = shape
        specs = sharded_eh_specs(
            {"keys": Shaped((16, 1024)),
             "directories": Shaped((16, 1 << 14)),
             "global_depths": Shaped((16,))}, real)
        assert specs["keys"].spec == P("data")
        assert specs["directories"].spec == P("data", "model")
        assert specs["global_depths"].spec == P()


class TestKVViewSpecs:
    """Stacked per-shard KV view arrays place via the same
    divisibility-aware rules (kv_shard ~ eh_shard)."""

    def test_stacked_view_names(self, mesh):
        # 16 shards over data; kv_heads over model; ctx/seqs replicate
        # once their candidate axes are consumed
        spec = logical_spec((16, 4, 64, 128, 16, 128),
                            ("kv_shard", "layer", "kv_seqs", "ctx",
                             "kv_heads", "head_dim"), mesh)
        assert spec == P("data", None, None, None, "model")

    def test_indivisible_shards_replicate(self, mesh):
        # 2 shards cannot split a 16-way data axis -> the shard dim
        # replicates and kv_seqs claims the freed data axis instead
        spec = logical_spec((2, 4, 64, 128, 16, 128),
                            ("kv_shard", "layer", "kv_seqs", "ctx",
                             "kv_heads", "head_dim"), mesh)
        assert spec == P(None, None, "data", None, "model")

    def test_sharded_kv_view_specs_helper(self):
        import numpy as np
        from jax.sharding import Mesh
        from repro.distributed.sharding import sharded_kv_view_specs
        real = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))

        class Shaped:
            def __init__(self, shape):
                self.shape = shape
        shape = (8, 2, 4, 32, 2, 8)
        specs = sharded_kv_view_specs(
            {"view_k": Shaped(shape), "view_v": Shaped(shape)}, real)
        assert specs["view_k"].spec == P("data", None, None, None, "model")
        assert specs["view_v"].spec == specs["view_k"].spec


class TestParamNames:
    def test_names_cover_all_leaves(self):
        import jax.numpy as jnp
        from repro.configs import get
        from repro.models.model import init_params
        cfg = get("hymba_1_5b").reduced()   # attn + ssm + mlp
        params = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
        names = param_names(params)
        flat_p = jax.tree.leaves(params)
        flat_n = jax.tree.leaves(names, is_leaf=lambda x: isinstance(
            x, list))
        assert len(flat_p) == len(flat_n)
        for p, n in zip(flat_p, flat_n):
            assert len(n) == p.ndim


_SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.distributed import sharding as shd
    from repro.models.model import init_params
    from repro.optim.adamw import adamw_init
    from repro.optim.schedule import wsd_schedule
    from repro.runtime.train import make_train_step

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get("internlm2_1_8b").reduced()
    pipe = SyntheticLM(cfg, DataConfig(seq_len=32, global_batch=8))
    p_host = init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    params = jax.device_put(p_host, shd.param_specs(p_host, mesh))
    opt = adamw_init(params)
    step = make_train_step(cfg, lr_fn=lambda s: wsd_schedule(
        s, peak_lr=1e-2, warmup_steps=2, total_steps=100),
        remat=False).fn
    with shd.activate_mesh(mesh):
        jitted = jax.jit(step)
        losses = []
        for i in range(8):
            b = pipe.batch(i)
            batch = jax.device_put(b, shd.batch_spec(b, mesh))
            params, opt, metrics = jitted(params, opt, batch)
            losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], losses
    # the params really are distributed
    w = jax.tree.leaves(params)[0]
    assert len(w.sharding.device_set) > 1
    print(json.dumps({"losses": losses}))
""")


def test_multi_device_train_step_subprocess():
    """End-to-end sharded training on an 8-device host mesh: loss is
    finite, decreasing, and the program actually partitions."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_PROG], env=env,
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["losses"][-1] < result["losses"][0]
