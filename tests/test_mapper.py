"""The generic shortcut-maintenance runtime (``runtime/mapper.py``):
version monotonicity, create-collapses-updates batching, async/pump
equivalence, routing policies, and EH<->KV client parity."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.shortcut_eh import ShortcutEH
from repro.kvcache import paged_cache as pc
from repro.kvcache.shortcut_cache import ShortcutKVManager
from repro.runtime.mapper import (CREATE, GLOBAL_VIEW, FanInRouting,
                                  FragmentationRouting, HysteresisRouting,
                                  Request, ShortcutMapper)

from conftest import unique_keys


class ToyClient:
    """Minimal runtime client: authoritative dict, dict-replica view."""

    def __init__(self, **kw):
        self.data = {}
        self.view = {}
        self.create_calls = 0
        self.update_keys = []
        self.mapper = ShortcutMapper(
            replay_create=self._replay_create,
            replay_update=self._replay_update,
            snapshot=lambda: dict(self.data),
            view_arrays=tuple,
            routing=kw.pop("routing", FanInRouting(8.0)), **kw)

    def put(self, key, val, kind="update"):
        with self.mapper.lock:
            self.data[key] = val
            versions = self.mapper.record([GLOBAL_VIEW])
        if kind == "create":
            self.mapper.submit_create([GLOBAL_VIEW], versions)
        else:
            self.mapper.submit_update([GLOBAL_VIEW], versions,
                                      payload=(key, val))

    def _replay_create(self, snap, requests):
        self.create_calls += 1
        self.view = dict(snap)

    def _replay_update(self, snap, requests):
        for r in requests:
            key, val = r.payload
            self.view[key] = val
            self.update_keys.append(key)


class TestVersioning:
    def test_monotone_and_gated(self):
        t = ToyClient()
        for i in range(3):
            t.put(f"k{i}", i)
            trad, sc = t.mapper.versions(GLOBAL_VIEW)
            assert sc < trad and not t.mapper.in_sync([GLOBAL_VIEW])
            t.mapper.pump()
            trad, sc = t.mapper.versions(GLOBAL_VIEW)
            assert sc == trad == i + 1
            assert t.mapper.in_sync([GLOBAL_VIEW])
        assert t.view == t.data

    def test_publish_never_decreases(self):
        t = ToyClient()
        t.put("a", 1)
        t.put("b", 2)
        t.mapper.pump()
        sc_after = t.mapper.sc_version(GLOBAL_VIEW)
        # a stale request (older version) must not move sc_version back
        t.mapper.submit_update([GLOBAL_VIEW], [1], payload=("a", 1))
        t.mapper.pump()
        assert t.mapper.sc_version(GLOBAL_VIEW) == sc_after

    def test_invalidate_desyncs(self):
        t = ToyClient()
        t.put("a", 1)
        t.mapper.pump()
        assert t.mapper.in_sync([GLOBAL_VIEW])
        with t.mapper.lock:
            t.mapper.invalidate([GLOBAL_VIEW])
        assert not t.mapper.in_sync([GLOBAL_VIEW])
        assert t.mapper.sc_version(GLOBAL_VIEW) == -1


class TestCollapse:
    def test_create_collapses_pending_updates_at_enqueue(self):
        t = ToyClient()
        t.put("a", 1)
        t.put("b", 2)
        t.put("c", 3, kind="create")    # drains + pops the two updates
        assert t.mapper.stats.collapsed == 2
        t.mapper.pump()
        assert t.create_calls == 1
        assert t.update_keys == []      # stale updates never replayed
        assert t.view == {"a": 1, "b": 2, "c": 3}
        assert t.mapper.in_sync([GLOBAL_VIEW])

    def test_batch_side_collapse_catches_races(self):
        """An update that races past the enqueue-time drain (older version,
        behind a create in the FIFO) is dropped by the batch-side rule."""
        t = ToyClient()
        with t.mapper.lock:
            (v1,) = t.mapper.record([GLOBAL_VIEW])
            t.data["x"] = 1
            (v2,) = t.mapper.record([GLOBAL_VIEW])
            t.data["y"] = 2
        t.mapper._queue.put(Request(CREATE, {GLOBAL_VIEW: v2}))
        t.mapper.submit_update([GLOBAL_VIEW], [v1], payload=("x", 1))
        t.mapper.pump()
        assert t.update_keys == []
        assert t.mapper.stats.collapsed == 1
        assert t.mapper.in_sync([GLOBAL_VIEW])

    def test_newer_update_survives_create(self):
        """FIFO order: create, then a *newer* update — both replay, the
        update after the create."""
        t = ToyClient()
        t.put("a", 1, kind="create")
        t.put("b", 2)                   # newer than the create
        t.mapper.pump()
        assert t.create_calls == 1
        assert t.update_keys == ["b"]
        assert t.view == {"a": 1, "b": 2}

    def test_per_key_collapse_is_not_global(self):
        """A create for one view key must not collapse another key's
        pending update (the KV cache relies on this)."""
        t = ToyClient()
        with t.mapper.lock:
            (vs0,) = t.mapper.record(["seq0"])
            (vs1,) = t.mapper.record(["seq1"])
        t.mapper.submit_update(["seq1"], [vs1], payload=("s1", 1))
        t.mapper.submit_create(["seq0"], [vs0])
        assert t.mapper.stats.collapsed == 0
        t.mapper.pump()
        assert t.update_keys == ["s1"]
        assert t.mapper.in_sync(["seq0", "seq1"])


class TestAsyncEquivalence:
    def test_async_mapper_matches_pump(self, rng):
        """The mapper thread and the synchronous pump() surrogate must
        converge to identical shortcut views."""
        keys = unique_keys(rng, 300)
        vals = np.arange(300, dtype=np.uint32)
        results = {}
        for mode in ("pump", "async"):
            with ShortcutEH(max_global_depth=8, bucket_slots=16,
                            capacity=512, poll_interval=0.003,
                            async_mapper=(mode == "async")) as sc:
                for i in range(0, 300, 60):
                    sc.insert(keys[i:i + 60], vals[i:i + 60])
                if mode == "pump":
                    sc.pump()
                assert sc.wait_in_sync(timeout=30.0)
                results[mode] = (np.array(sc.view_keys),
                                 np.array(sc.view_vals),
                                 sc.versions())
        np.testing.assert_array_equal(results["pump"][0],
                                      results["async"][0])
        np.testing.assert_array_equal(results["pump"][1],
                                      results["async"][1])
        assert results["pump"][2] == results["async"][2]


class TestRoutingPolicies:
    def test_fan_in_flips_at_threshold(self):
        pol = FanInRouting(8.0)
        assert pol.decide(8.0) and pol.decide(1.0)
        assert not pol.decide(8.0 + 1e-9)

    def test_fragmentation_flips_at_threshold(self):
        pol = FragmentationRouting(0.25)
        assert pol.decide(0.25) and pol.decide(1.0)
        assert not pol.decide(0.25 - 1e-9)

    def test_hysteresis_holds_between_bands(self):
        pol = HysteresisRouting(FanInRouting(6.0), FanInRouting(10.0))
        assert not pol.decide(7.0)      # never engaged, above enter band
        assert pol.decide(5.0)          # engages
        assert pol.decide(9.0)          # holds inside the band
        assert not pol.decide(11.0)     # disengages past exit
        assert not pol.decide(9.0)      # and stays off inside the band

    def test_gate_requires_sync_and_policy(self):
        t = ToyClient(routing=FanInRouting(8.0))
        t.put("a", 1)
        assert not t.mapper.gate(1.0, [GLOBAL_VIEW])   # out of sync
        t.mapper.pump()
        assert t.mapper.gate(1.0, [GLOBAL_VIEW])
        assert not t.mapper.gate(9.0, [GLOBAL_VIEW])   # policy refuses

    def test_eh_accepts_custom_routing(self, rng):
        keys = unique_keys(rng, 50)
        sc = ShortcutEH(max_global_depth=8, bucket_slots=64, capacity=128,
                        routing=HysteresisRouting(FanInRouting(6.0),
                                                  FanInRouting(10.0)))
        sc.insert(keys, np.arange(50, dtype=np.uint32))
        sc.pump()
        out = np.asarray(sc.lookup(keys))
        np.testing.assert_array_equal(out, np.arange(50, dtype=np.uint32))
        assert sc.fan_in_threshold is None   # no scalar threshold to report
        with pytest.raises(AttributeError):
            sc.fan_in_threshold = 4.0


class TestClientParity:
    """EH and KV drive the SAME runtime class and must show identical
    maintenance semantics: stale until pumped, in sync after, shortcut
    and fallback reads agree."""

    def test_same_runtime_class(self, rng):
        sc = ShortcutEH(max_global_depth=8, bucket_slots=16, capacity=64)
        cache = pc.cache_create(2, 64, 4, 2, 8, 4, 16, dtype=jnp.float32)
        mgr = ShortcutKVManager(cache, seq_capacity=64)
        assert type(sc.mapper) is ShortcutMapper
        assert type(mgr.mapper) is ShortcutMapper

    def test_parity_stale_then_sync_then_agree(self, rng):
        # EH client
        keys = unique_keys(rng, 120)
        sc = ShortcutEH(max_global_depth=8, bucket_slots=16, capacity=256)
        sc.insert(keys, np.arange(120, dtype=np.uint32))
        eh_stale = not sc.in_sync()
        sc.pump()
        from repro.core import extendible_hashing as eh
        trad = np.asarray(eh.eh_lookup_many(sc.state, jnp.asarray(keys)))
        short = np.asarray(eh.shortcut_lookup_many(
            sc.view_keys, sc.view_vals, sc.state.global_depth,
            jnp.asarray(keys)))
        # KV client
        cache = pc.cache_create(2, 64, 4, 2, 8, 4, 16, dtype=jnp.float32)
        mgr = ShortcutKVManager(cache, seq_capacity=64)
        k = jnp.asarray(rng.normal(size=(2, 2, 8, 2, 8)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(2, 2, 8, 2, 8)).astype(np.float32))
        mgr.prefill(np.array([0, 1]), k, v)
        kv_stale = not mgr.in_sync(np.array([0, 1]))
        mgr.pump()
        kp, vp, _ = mgr.get_context(np.array([0, 1]), route="paged")
        ks, vs, _ = mgr.get_context(np.array([0, 1]), route="shortcut")

        assert eh_stale and kv_stale           # parity: async by default
        assert sc.in_sync() and mgr.in_sync(np.array([0, 1]))
        np.testing.assert_array_equal(trad, short)
        sl = int(mgr.seq_lens(np.array([0]))[0])
        np.testing.assert_allclose(np.asarray(kp[:, :, :, :sl]),
                                   np.asarray(ks[:, :, :, :sl]))
        np.testing.assert_allclose(np.asarray(vp[:, :, :, :sl]),
                                   np.asarray(vs[:, :, :, :sl]))
        # both published their maintenance through the runtime stats
        assert sc.mapper.stats.creates + sc.mapper.stats.updates >= 1
        assert mgr.mapper.stats.creates >= 1
