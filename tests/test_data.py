"""Data pipeline: determinism, shard disjointness, modality stubs."""
import jax
import numpy as np
import pytest

from repro.configs import get
from repro.data.pipeline import DataConfig, SyntheticLM, make_batch_specs


def test_batches_deterministic():
    cfg = get("internlm2_1_8b").reduced()
    pipe = SyntheticLM(cfg, DataConfig(seq_len=32, global_batch=8, seed=7))
    a = pipe.batch(step=3, shard=1, num_shards=4)
    b = pipe.batch(step=3, shard=1, num_shards=4)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))


def test_steps_and_shards_differ():
    cfg = get("internlm2_1_8b").reduced()
    pipe = SyntheticLM(cfg, DataConfig(seq_len=32, global_batch=8))
    s0 = np.asarray(pipe.batch(0, 0, 4)["tokens"])
    s1 = np.asarray(pipe.batch(1, 0, 4)["tokens"])
    o1 = np.asarray(pipe.batch(0, 1, 4)["tokens"])
    assert not (s0 == s1).all()
    assert not (s0 == o1).all()


def test_labels_are_next_tokens():
    cfg = get("internlm2_1_8b").reduced()
    pipe = SyntheticLM(cfg, DataConfig(seq_len=32, global_batch=4))
    b = pipe.batch(0)
    np.testing.assert_array_equal(np.asarray(b["tokens"])[:, 1:],
                                  np.asarray(b["labels"])[:, :-1])


def test_reshard_preserves_global_batch():
    """Elastic rescale: 2 shards x b/2 vs 4 shards x b/4 cover different
    partitions but each is internally consistent."""
    cfg = get("internlm2_1_8b").reduced()
    pipe = SyntheticLM(cfg, DataConfig(seq_len=16, global_batch=8))
    two = [pipe.batch(0, s, 2)["tokens"].shape[0] for s in range(2)]
    four = [pipe.batch(0, s, 4)["tokens"].shape[0] for s in range(4)]
    assert sum(two) == sum(four) == 8


def test_modality_stubs():
    mg = get("musicgen_medium").reduced()
    pipe = SyntheticLM(mg, DataConfig(seq_len=16, global_batch=2))
    b = pipe.batch(0)
    assert b["embeddings"].shape == (2, 16, mg.d_model)
    pg = get("paligemma_3b").reduced()
    pipe = SyntheticLM(pg, DataConfig(seq_len=16, global_batch=2))
    b = pipe.batch(0)
    assert b["prefix_embeddings"].shape == (2, pg.prefix_len, pg.d_model)
    assert b["tokens"].shape == (2, 16 - pg.prefix_len)


def test_specs_match_real_batches():
    for arch in ["internlm2_1_8b", "musicgen_medium", "paligemma_3b"]:
        cfg = get(arch).reduced()
        specs = make_batch_specs(cfg, 32, 4)
        pipe = SyntheticLM(cfg, DataConfig(seq_len=32, global_batch=4))
        real = pipe.batch(0)
        assert set(specs) == set(real), arch
        for k in specs:
            assert tuple(specs[k].shape) == tuple(real[k].shape), (arch, k)


def test_loss_decreases_on_synthetic_stream():
    """The stream is learnable: a few training steps reduce loss below
    the log(V) random floor."""
    import jax.numpy as jnp
    from repro.models import model as M
    from repro.optim.adamw import adamw_init, adamw_update
    cfg = get("internlm2_1_8b").reduced()
    pipe = SyntheticLM(cfg, DataConfig(seq_len=64, global_batch=8))
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: M.train_forward(p, cfg, batch, remat=False))(params)
        params, opt, _ = adamw_update(grads, opt, params,
                                      lr=jnp.float32(1e-2),
                                      weight_decay=0.0)
        return params, opt, loss

    losses = []
    for i in range(30):
        params, opt, loss = step(params, opt, pipe.batch(i))
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert min(losses[-5:]) < losses[0] - 0.5, losses[:3] + losses[-3:]
