"""PagePool + compose/remap: the rewiring abstraction (paper §2)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, never hard-fail
from hypothesis import given, settings, strategies as st

from repro.core import rewiring as rw


def test_pool_alloc_free_cycle():
    pool = rw.pool_create(capacity=8, page_slots=4)
    offs = []
    for _ in range(8):
        pool, off = rw.pool_alloc(pool)
        offs.append(int(off))
    assert sorted(offs) == list(range(8))
    pool, off = rw.pool_alloc(pool)
    assert int(off) == -1                       # exhausted
    pool = rw.pool_free(pool, jnp.int32(3))
    pool, off = rw.pool_alloc(pool)
    assert int(off) == 3                        # recycled from the ring
    assert int(rw.pool_used_pages(pool)) == 8


@settings(deadline=None, max_examples=15)
@given(st.lists(st.booleans(), min_size=1, max_size=60))
def test_pool_never_double_allocates(ops):
    """Property: live offsets are always distinct (free ring correctness)."""
    pool = rw.pool_create(capacity=16, page_slots=2)
    live = []
    for do_alloc in ops:
        if do_alloc or not live:
            pool, off = rw.pool_alloc(pool)
            if int(off) >= 0:
                assert int(off) not in live
                live.append(int(off))
        else:
            pool = rw.pool_free(pool, jnp.int32(live.pop()))
    assert len(live) == len(set(live))
    assert int(rw.pool_used_pages(pool)) == len(live)


def test_compose_matches_gather(rng):
    pages = jnp.asarray(rng.normal(size=(10, 4)).astype(np.float32))
    directory = jnp.asarray([3, 3, 1, 0, 7], jnp.int32)
    view = rw.compose(pages, directory)
    np.testing.assert_array_equal(np.asarray(view),
                                  np.asarray(pages)[np.asarray(directory)])


def test_remap_slots_last_write_wins(rng):
    pages = jnp.asarray(rng.normal(size=(10, 4)).astype(np.float32))
    view = jnp.zeros((6, 4), jnp.float32)
    slots = jnp.asarray([2, 2, 5], jnp.int32)    # duplicate slot 2
    offs = jnp.asarray([1, 7, 3], jnp.int32)
    out = rw.remap_slots(view, pages, slots, offs)
    np.testing.assert_array_equal(np.asarray(out[2]), np.asarray(pages[7]))
    np.testing.assert_array_equal(np.asarray(out[5]), np.asarray(pages[3]))
    np.testing.assert_array_equal(np.asarray(out[0]), np.zeros(4))


def test_remap_range_broadcasts_one_page(rng):
    pages = jnp.asarray(rng.normal(size=(10, 4)).astype(np.float32))
    view = jnp.zeros((8, 4), jnp.float32)
    out = rw.remap_range(view, pages, jnp.int32(2), 4, jnp.int32(6))
    for i in range(2, 6):
        np.testing.assert_array_equal(np.asarray(out[i]),
                                      np.asarray(pages[6]))
    np.testing.assert_array_equal(np.asarray(out[1]), np.zeros(4))
    np.testing.assert_array_equal(np.asarray(out[6]), np.zeros(4))
