"""Pallas kernel sweeps: shapes x dtypes against the ref.py oracles
(interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.eh_lookup import (eh_lookup, sharded_eh_lookup,
                                     sharded_shortcut_lookup,
                                     shortcut_lookup)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ragged_copy import ragged_copy
from repro.kernels.shortcut_attention import shortcut_attention

from conftest import unique_keys


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,KV,G,Sq,hd,bq,bkv",
        [(1, 1, 1, 64, 16, 16, 32),
         (2, 2, 4, 128, 32, 32, 64),
         (1, 4, 2, 96, 64, 32, 32),    # ragged: 96 % 64 != 0
         (2, 1, 8, 128, 128, 64, 128)])
    def test_causal_sweep(self, dtype, B, KV, G, Sq, hd, bq, bkv):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, KV, G, Sq, hd), dtype)
        k = jax.random.normal(ks[1], (B, KV, Sq, hd), dtype)
        v = jax.random.normal(ks[2], (B, KV, Sq, hd), dtype)
        out = flash_attention(q, k, v, bq=bq, bkv=bkv)
        want = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            **tol(dtype))

    @pytest.mark.parametrize("window", [16, 33, 100])
    def test_sliding_window(self, window):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 2, 2, 128, 32), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 128, 32), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 128, 32), jnp.float32)
        out = flash_attention(q, k, v, bq=32, bkv=32, window=window)
        want = ref.flash_attention_ref(q, k, v, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_softcap(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (1, 1, 2, 64, 32), jnp.float32) * 3
        k = jax.random.normal(ks[1], (1, 1, 64, 32), jnp.float32) * 3
        v = jax.random.normal(ks[2], (1, 1, 64, 32), jnp.float32)
        out = flash_attention(q, k, v, bq=32, bkv=32, softcap=20.0)
        want = ref.flash_attention_ref(q, k, v, softcap=20.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_prefill_shorter_q(self):
        """Right-aligned q against a longer kv (chunked prefill shape)."""
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (1, 2, 2, 32, 32), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 128, 32), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 128, 32), jnp.float32)
        out = flash_attention(q, k, v, bq=32, bkv=32)
        want = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


class TestDecodeKernels:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,KV,G,hd,S,bs",
                             [(2, 1, 4, 32, 64, 16),
                              (3, 2, 2, 64, 96, 32),
                              (1, 4, 1, 128, 128, 128)])
    def test_shortcut_sweep(self, dtype, B, KV, G, hd, S, bs):
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        q = jax.random.normal(ks[0], (B, KV, G, hd), dtype)
        kv = jax.random.normal(ks[1], (2, B, KV, S, hd), dtype)
        ctx = jnp.asarray(
            np.random.default_rng(0).integers(1, S + 1, B), jnp.int32)
        out = shortcut_attention(q, kv[0], kv[1], ctx, bs=bs)
        want = ref.decode_attention_ref(q, kv[0], kv[1], ctx)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            **tol(dtype))

    def test_shortcut_window(self):
        ks = jax.random.split(jax.random.PRNGKey(5), 3)
        q = jax.random.normal(ks[0], (2, 2, 2, 32), jnp.float32)
        kv = jax.random.normal(ks[1], (2, 2, 2, 96, 32), jnp.float32)
        ctx = jnp.asarray([96, 41], jnp.int32)
        out = shortcut_attention(q, kv[0], kv[1], ctx, bs=32, window=17)
        want = ref.decode_attention_ref(q, kv[0], kv[1], ctx, window=17)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,KV,G,hd,bs,nb,MB",
                             [(2, 2, 2, 32, 16, 24, 6),
                              (3, 1, 4, 64, 8, 48, 8)])
    def test_paged_sweep(self, dtype, B, KV, G, hd, bs, nb, MB):
        ks = jax.random.split(jax.random.PRNGKey(6), 3)
        q = jax.random.normal(ks[0], (B, KV, G, hd), dtype)
        kp = jax.random.normal(ks[1], (nb, KV, bs, hd), dtype)
        vp = jax.random.normal(ks[2], (nb, KV, bs, hd), dtype)
        rng = np.random.default_rng(1)
        tables = np.full((B, MB), -1, np.int32)
        lens = rng.integers(1, MB * bs + 1, B).astype(np.int32)
        pool = list(rng.permutation(nb))
        for b in range(B):
            for m in range(-(-int(lens[b]) // bs)):
                tables[b, m] = pool.pop()
        out = paged_attention(q, kp, vp, jnp.asarray(tables),
                              jnp.asarray(lens))
        want = ref.paged_attention_ref(q, kp, vp, jnp.asarray(tables),
                                       jnp.asarray(lens))
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            **tol(dtype))

    def test_paged_equals_shortcut_when_linear(self):
        """Identity block table => both paths must agree exactly (the
        paper's Figure 1 equivalence)."""
        B, KV, G, hd, bs, MB = 2, 2, 2, 32, 8, 6
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        q = jax.random.normal(ks[0], (B, KV, G, hd), jnp.float32)
        kp = jax.random.normal(ks[1], (B * MB, KV, bs, hd), jnp.float32)
        vp = jax.random.normal(ks[2], (B * MB, KV, bs, hd), jnp.float32)
        tables = jnp.arange(B * MB, dtype=jnp.int32).reshape(B, MB)
        lens = jnp.asarray([MB * bs, 3 * bs + 2], jnp.int32)
        paged = paged_attention(q, kp, vp, tables, lens)
        # pool (B*MB, KV, bs, hd) -> contiguous view (B, KV, MB*bs, hd)
        view = kp.reshape(B, MB, KV, bs, hd).transpose(
            0, 2, 1, 3, 4).reshape(B, KV, MB * bs, hd)
        view_v = vp.reshape(B, MB, KV, bs, hd).transpose(
            0, 2, 1, 3, 4).reshape(B, KV, MB * bs, hd)
        short = shortcut_attention(q, view, view_v, lens, bs=bs)
        np.testing.assert_allclose(np.asarray(paged), np.asarray(short),
                                   rtol=1e-6, atol=1e-6)


class TestEHKernels:
    @pytest.mark.parametrize("n,slots,tile", [(200, 16, 64),
                                              (1000, 8, 256)])
    def test_lookup_sweep(self, rng, n, slots, tile):
        from repro.core import extendible_hashing as eh
        keys = unique_keys(rng, n)
        st = eh.eh_create(max_global_depth=9, bucket_slots=slots,
                          capacity=1024)
        st = eh.eh_insert_many(st, jnp.asarray(keys),
                               jnp.asarray(np.arange(n, dtype=np.uint32)))
        D = 1 << int(st.global_depth)
        probe = np.concatenate(
            [keys, unique_keys(rng, 100, lo=2**31, hi=2**32 - 2)])
        out = eh_lookup(jnp.asarray(probe), st.directory[:D],
                        st.bucket_keys, st.bucket_vals, st.global_depth,
                        tile=tile)
        want = ref.eh_lookup_ref(jnp.asarray(probe), st.directory[:D],
                                 st.bucket_keys, st.bucket_vals,
                                 st.global_depth)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    @pytest.mark.parametrize("N", [1, 4])
    def test_sharded_kernel_matches_per_shard(self, rng, N):
        """One grid-over-shards dispatch == N independent single-shard
        calls (the shard loop shares one pallas_call specialization)."""
        from repro.core import extendible_hashing as eh
        states, probes = [], []
        for s in range(N):
            keys = unique_keys(rng, 150 + 40 * s)
            st = eh.eh_create(max_global_depth=8, bucket_slots=8,
                              capacity=256)
            st = eh.eh_insert_many(
                st, jnp.asarray(keys),
                jnp.asarray(np.arange(keys.size, dtype=np.uint32)))
            states.append(st)
            probes.append(np.concatenate(
                [keys, unique_keys(rng, 50, lo=2**31, hi=2**32 - 2)]))
        K = max(p.size for p in probes)
        padded = np.zeros((N, K), np.uint32)
        for s, p in enumerate(probes):
            padded[s, :p.size] = p
        out = sharded_eh_lookup(
            jnp.asarray(padded),
            jnp.stack([st.directory for st in states]),
            jnp.stack([st.bucket_keys for st in states]),
            jnp.stack([st.bucket_vals for st in states]),
            jnp.asarray([int(st.global_depth) for st in states],
                        jnp.int32), tile=64)
        D = states[0].directory.shape[0]
        for s, st in enumerate(states):
            want = eh_lookup(jnp.asarray(padded[s]), st.directory[:D],
                             st.bucket_keys, st.bucket_vals,
                             st.global_depth, tile=64)
            np.testing.assert_array_equal(np.asarray(out[s]),
                                          np.asarray(want))
        # shortcut flavour over shape-uniform composed views
        V = 1 << max(int(st.global_depth) for st in states)
        views = [eh.compose_shortcut(st, V) for st in states]
        out_sc = sharded_shortcut_lookup(
            jnp.asarray(padded),
            jnp.stack([vk for vk, _ in views]),
            jnp.stack([vv for _, vv in views]),
            jnp.asarray([int(st.global_depth) for st in states],
                        jnp.int32), tile=64)
        for s, st in enumerate(states):
            want = shortcut_lookup(jnp.asarray(padded[s]), *views[s],
                                   st.global_depth, tile=64)
            np.testing.assert_array_equal(np.asarray(out_sc[s]),
                                          np.asarray(want))

    def test_shortcut_kernel_matches_traditional(self, rng):
        from repro.core import extendible_hashing as eh
        keys = unique_keys(rng, 500)
        st = eh.eh_create(max_global_depth=8, bucket_slots=16,
                          capacity=512)
        st = eh.eh_insert_many(
            st, jnp.asarray(keys),
            jnp.asarray(np.arange(500, dtype=np.uint32)))
        D = 1 << int(st.global_depth)
        vk, vv = eh.compose_shortcut(st, D)
        probe = jnp.asarray(keys)
        trad = eh_lookup(probe, st.directory[:D], st.bucket_keys,
                         st.bucket_vals, st.global_depth, tile=128)
        short = shortcut_lookup(probe, vk, vv, st.global_depth, tile=128)
        np.testing.assert_array_equal(np.asarray(trad), np.asarray(short))


class TestRaggedCopy:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16,
                                       jnp.uint32])
    @pytest.mark.parametrize("row", [(8,), (4, 6)])
    def test_sweep(self, rng, dtype, row):
        view = jnp.asarray(
            rng.normal(size=(20,) + row).astype(np.float32)).astype(dtype)
        pool = jnp.asarray(
            rng.normal(size=(40,) + row).astype(np.float32)).astype(dtype)
        slots = jnp.asarray(rng.choice(20, 7, replace=False), jnp.int32)
        offs = jnp.asarray(rng.choice(40, 7), jnp.int32)
        out = ragged_copy(view, pool, slots, offs)
        want = ref.ragged_copy_ref(view, pool, slots, offs)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))
