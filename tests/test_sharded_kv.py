"""Per-shard KV view arrays (kvcache/shortcut_cache, DESIGN.md §4.2):
lock-free replays, atomic per-shard publication (no torn views), the
under-lock position read, cross-shard get_context order, and randomized
parity of ShortcutKVManager(num_shards=N) vs the single-shard manager
with async mappers + a tear-detector thread.
"""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kvcache import paged_cache as pc
from repro.kvcache.shortcut_cache import ShortcutKVManager

L, BS, KV, HD = 2, 4, 2, 8
MAX_SEQS, CAP = 8, 32


def make_mgr(num_shards, **kw):
    cache = pc.cache_create(L, MAX_SEQS * (CAP // BS) * 2, BS, KV, HD,
                            MAX_SEQS, CAP // BS, dtype=jnp.float32)
    return ShortcutKVManager(cache, seq_capacity=CAP,
                             num_shards=num_shards, **kw)


def paired_kv(rng, B, S):
    """k random, v = -k: any reader pairing a view_k from one
    publication with a view_v from another breaks v == -k somewhere
    (zeros pair with zeros, so unwritten positions stay consistent)."""
    k = jnp.asarray(rng.normal(size=(L, B, S, KV, HD)).astype(np.float32))
    return k, -k


class TestLockFreeReplay:
    def test_view_lock_is_gone(self):
        mgr = make_mgr(4)
        assert not hasattr(mgr, "_view_lock")
        mgr.close()

    def test_replay_acquires_no_cross_shard_lock(self, rng):
        """Replaying shard 0 while another thread holds shard 1's lock
        must not block: the replay path touches only shard-own state."""
        mgr = make_mgr(2)
        k, v = paired_kv(rng, 2, 8)
        mgr.prefill(np.array([0, 2]), k, v)          # both shard 0
        done = threading.Event()

        def pump_shard0():
            mgr.group[0].pump()
            done.set()

        with mgr.group[1].lock:                      # foreign lock held
            t = threading.Thread(target=pump_shard0)
            t.start()
            t.join(timeout=30.0)
        assert done.is_set(), "shard-0 replay blocked on shard 1's lock"
        assert mgr.in_sync(np.array([0, 2]))
        mgr.close()

    def test_atomic_tuple_publication(self, rng):
        """One registry snapshot is one publication: k and v always come
        from the same replay (v == -k by construction)."""
        mgr = make_mgr(2)
        k, v = paired_kv(rng, 2, 8)
        mgr.prefill(np.array([0, 1]), k, v)
        mgr.pump()
        for s in range(2):
            vk, vv = mgr.views.snapshot(s)
            np.testing.assert_array_equal(np.asarray(vv), -np.asarray(vk))
        mgr.close()


class TestRacingAppenders:
    def test_positions_read_under_lock(self, rng):
        """Regression for the racy position read: two appenders racing on
        the same sequence must see strictly increasing positions — the
        pre-fix code read seq_lens before taking the shard locks, so both
        could capture the same position and the view lost a token."""
        mgr = make_mgr(1)
        k, v = paired_kv(rng, 1, BS)
        mgr.prefill(np.array([0]), k, v)
        mgr.pump()

        seen = []
        orig = mgr.group[0].submit_update

        def spy(keys, versions, payload=None):
            seen.append(np.asarray(payload[1]).copy())
            orig(keys, versions, payload=payload)

        mgr.group[0].submit_update = spy
        T = 8
        barrier = threading.Barrier(2)
        errors = []

        def appender(seed):
            r = np.random.default_rng(seed)
            barrier.wait()
            try:
                for _ in range(T):
                    nk, nv = paired_kv(r, 1, 1)
                    mgr.append(np.array([0]), nk[:, :, 0], nv[:, :, 0])
            except Exception as e:           # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=appender, args=(s,))
                   for s in (1, 2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        positions = np.sort(np.concatenate(seen))
        np.testing.assert_array_equal(positions, np.arange(BS, BS + 2 * T))
        assert int(mgr.seq_lens(np.array([0]))[0]) == BS + 2 * T
        mgr.pump()
        kp, vp, _ = mgr.get_context(np.array([0]), route="paged")
        ks, vs, _ = mgr.get_context(np.array([0]), route="shortcut")
        sl = BS + 2 * T
        np.testing.assert_array_equal(np.asarray(kp[:, :, :, :sl]),
                                      np.asarray(ks[:, :, :, :sl]))
        np.testing.assert_array_equal(np.asarray(vp[:, :, :, :sl]),
                                      np.asarray(vs[:, :, :, :sl]))
        mgr.close()


class TestRouteAttribution:
    def test_multi_shard_batch_hits_group_counter(self, rng):
        """A batch-level route decision spanning shards lands on the
        group-level counter — shard 0's per-shard stats must not move
        (the old default misattributed every batch to shard 0)."""
        mgr = make_mgr(2)
        k, v = paired_kv(rng, 2, 8)
        mgr.prefill(np.array([0, 1]), k, v)          # one seq per shard
        mgr.pump()
        mgr.get_context(np.array([0, 1]), route="shortcut")
        mgr.get_context(np.array([0, 1]), route="paged")
        assert mgr.routed_shortcut == 1 and mgr.routed_paged == 1
        for s in range(2):
            assert mgr.group[s].routed_shortcut == 0
            assert mgr.group[s].routed_fallback == 0
        mgr.close()


class TestCrossShardContext:
    def test_get_context_scatter_back_order(self, rng):
        """A batch spanning shards in arbitrary order comes back in
        input order, bit-identical to the paged path."""
        mgr = make_mgr(2)
        k, v = paired_kv(rng, 4, 8)
        mgr.prefill(np.array([0, 1, 2, 3]), k, v)
        mgr.pump()
        ids = np.array([3, 0, 2, 1])                 # interleaved shards
        ks, vs, _ = mgr.get_context(ids, route="shortcut")
        kp, vp, _ = mgr.get_context(ids, route="paged")
        np.testing.assert_array_equal(np.asarray(ks[:, :, :, :8]),
                                      np.asarray(kp[:, :, :, :8]))
        np.testing.assert_array_equal(np.asarray(vs[:, :, :, :8]),
                                      np.asarray(vp[:, :, :, :8]))
        mgr.close()


class TestShardedParity:
    """num_shards=N vs num_shards=1 over a randomized schedule with the
    paper's async mapper threads on, plus a tear-detector thread
    asserting every observed per-shard (view_k, view_v) pair is
    version-consistent (v == -k holds only within one publication)."""

    @pytest.mark.parametrize("num_shards", [2, 4])
    def test_randomized_schedule_parity(self, rng, num_shards):
        mgr1 = make_mgr(1, async_mapper=True, poll_interval=0.002)
        mgrN = make_mgr(num_shards, async_mapper=True, poll_interval=0.002)

        tears = []
        stop = threading.Event()

        def tear_detector():
            while not stop.is_set():
                for s in range(num_shards):
                    vk, vv = mgrN.views.snapshot(s)
                    a, b = np.asarray(vk), np.asarray(vv)
                    if not np.array_equal(b, -a):
                        tears.append(s)
                        return

        det = threading.Thread(target=tear_detector, daemon=True)
        det.start()

        active: dict = {}                 # seq -> current length
        try:
            for step in range(30):
                op = rng.choice(["prefill", "append", "append",
                                 "release", "compare"])
                if op == "prefill":
                    free = [s for s in range(MAX_SEQS) if s not in active]
                    if not free:
                        continue
                    ids = rng.choice(free, size=min(2, len(free)),
                                     replace=False).astype(np.int64)
                    S = int(rng.choice([BS, 2 * BS, 3 * BS]))
                    k, v = paired_kv(rng, ids.size, S)
                    for m in (mgr1, mgrN):
                        m.prefill(ids, k, v)
                    for s in ids.tolist():
                        active[s] = S
                elif op == "append":
                    ids = [s for s, ln in active.items() if ln < CAP - 1]
                    if not ids:
                        continue
                    ids = np.asarray(sorted(rng.choice(
                        ids, size=min(3, len(ids)), replace=False)))
                    nk, nv = paired_kv(rng, ids.size, 1)
                    for m in (mgr1, mgrN):
                        m.append(ids, nk[:, :, 0], nv[:, :, 0])
                    for s in ids.tolist():
                        active[s] += 1
                elif op == "release" and active and rng.random() < 0.5:
                    s = int(rng.choice(sorted(active)))
                    for m in (mgr1, mgrN):
                        m.release(np.array([s]))
                    del active[s]
                elif op == "compare" and active:
                    ids = np.asarray(sorted(active))
                    rng.shuffle(ids)
                    assert mgr1.wait_in_sync(ids, timeout=60.0)
                    assert mgrN.wait_in_sync(ids, timeout=60.0)
                    k1, v1, _ = mgr1.get_context(ids, route="shortcut")
                    kN, vN, _ = mgrN.get_context(ids, route="shortcut")
                    # acceptance: bit-identical across shard counts
                    np.testing.assert_array_equal(np.asarray(k1),
                                                  np.asarray(kN))
                    np.testing.assert_array_equal(np.asarray(v1),
                                                  np.asarray(vN))
                    kp, vp, _ = mgrN.get_context(ids, route="paged")
                    for i, s in enumerate(ids.tolist()):
                        sl = active[s]
                        np.testing.assert_array_equal(
                            np.asarray(kN[:, i, :, :sl]),
                            np.asarray(kp[:, i, :, :sl]))
            # final settle + compare everything still active
            if active:
                ids = np.asarray(sorted(active))
                assert mgr1.wait_in_sync(ids, timeout=60.0)
                assert mgrN.wait_in_sync(ids, timeout=60.0)
                k1, v1, _ = mgr1.get_context(ids, route="shortcut")
                kN, vN, _ = mgrN.get_context(ids, route="shortcut")
                np.testing.assert_array_equal(np.asarray(k1),
                                              np.asarray(kN))
                np.testing.assert_array_equal(np.asarray(v1),
                                              np.asarray(vN))
        finally:
            stop.set()
            det.join(timeout=10.0)
            mgr1.close()
            mgrN.close()
        assert not tears, f"torn (view_k, view_v) pair on shards {tears}"
