"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on the synthetic pipeline, with checkpointing + restart.

This wraps the production launcher (repro.launch.train); everything —
data, sharding, remat, optimizer, async checkpoints, watchdog — is the
same code the multi-pod dry-run lowers.

  PYTHONPATH=src python examples/train_small.py [--steps 300]
"""
import argparse
import dataclasses
import sys

from repro.configs import get
from repro.configs.base import register
from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    args = ap.parse_args()

    # a ~100M-param member of the qwen3 family (registered on the fly —
    # any ArchConfig works as a --arch target)
    base = get("qwen3_4b")
    register(dataclasses.replace(
        base, name="qwen3_100m", num_layers=8, d_model=512, num_heads=8,
        num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768))

    return train_main([
        "--arch", "qwen3_100m",
        "--steps", str(args.steps),
        "--seq-len", "256",
        "--global-batch", "8",
        "--lr", "1e-3",
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100",
        "--log-every", "20",
    ])


if __name__ == "__main__":
    sys.exit(main())
