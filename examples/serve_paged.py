"""Serving example: batched requests through BOTH access paths.

Prefills a batch of prompts into the paged cache, decodes via (a) the
block-table path and (b) the contiguous shortcut view, checks the outputs
agree token-for-token, and prints the timing split — the KV-layer analogue
of the paper's Figure 2.

  PYTHONPATH=src python examples/serve_paged.py [--arch qwen3_4b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.kvcache import paged_cache as pc
from repro.models import model as M
from repro.runtime.serve import (make_paged_serve_step, make_prefill_step,
                                 make_serve_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get(args.arch).reduced()
    B, S, GEN = args.batch, args.prompt_len, args.gen
    s_cap = S + GEN + 8
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)

    # --- shortcut path ----------------------------------------------------
    prefill = make_prefill_step(cfg, s_cap=s_cap, dtype=jnp.float32)
    serve_s = jax.jit(make_serve_step(cfg))
    logits, state = prefill(params, {"tokens": toks})
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    short = [tok]
    t0 = time.perf_counter()
    for _ in range(GEN - 1):
        tok, state = serve_s(params, state, tok)
        short.append(tok)
    jax.block_until_ready(tok)
    t_short = time.perf_counter() - t0

    # --- paged path ---------------------------------------------------------
    bs = 8
    cache = pc.cache_create(
        cfg.num_layers, num_blocks=B * (s_cap // bs + 1), block_size=bs,
        kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
        max_seqs=B, max_blocks_per_seq=s_cap // bs + 1,
        dtype=jnp.float32)
    logits, caches = M.prefill_forward(params, cfg, {"tokens": toks})
    cache = pc.write_prefill(cache, jnp.arange(B), caches.k, caches.v)
    serve_p = jax.jit(make_paged_serve_step(cfg))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    seq_ids = jnp.arange(B, dtype=jnp.int32)
    paged = [tok]
    t0 = time.perf_counter()
    for _ in range(GEN - 1):
        tok, cache = serve_p(params, cache, tok, seq_ids)
        paged.append(tok)
    jax.block_until_ready(tok)
    t_paged = time.perf_counter() - t0

    short_np = np.stack([np.asarray(t) for t in short], 1)
    paged_np = np.stack([np.asarray(t) for t in paged], 1)
    assert (short_np == paged_np).all(), "access paths must agree!"
    print(f"arch={cfg.name} B={B} prompt={S} gen={GEN}")
    print(f"  paged decode:    {t_paged * 1e3:7.1f} ms  "
          f"({B * (GEN - 1) / t_paged:8.0f} tok/s)   [2 indirections]")
    print(f"  shortcut decode: {t_short * 1e3:7.1f} ms  "
          f"({B * (GEN - 1) / t_short:8.0f} tok/s)   [0 indirections]")
    print(f"  outputs identical across paths ✓  "
          f"sample: {short_np[0][:10].tolist()}")


if __name__ == "__main__":
    main()
