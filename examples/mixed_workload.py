"""Figure-8 style mixed workload, live: bulk load, then waves of inserts
+ lookups with the async mapper running — prints the version numbers and
per-wave lookup latency so the out-of-sync/catch-up cycle is visible.

  PYTHONPATH=src python examples/mixed_workload.py
"""
import time

import numpy as np

from repro.core.shortcut_eh import ShortcutEH


def main():
    rng = np.random.default_rng(7)
    n_bulk, n_wave = 20_000, 400
    keys = rng.choice(np.arange(1, 2**31, dtype=np.uint32),
                      size=n_bulk + 4 * n_wave, replace=False)

    with ShortcutEH(max_global_depth=14, bucket_slots=256, capacity=4096,
                    poll_interval=0.002, async_mapper=True) as sc:
        t0 = time.perf_counter()
        sc.insert(keys[:n_bulk], np.arange(n_bulk, dtype=np.uint32))
        sc.wait_in_sync()
        print(f"bulk-loaded {n_bulk} in {time.perf_counter() - t0:.2f}s; "
              f"depth={int(sc.state.global_depth)} "
              f"fan-in={sc.avg_fan_in():.2f}")

        inserted = n_bulk
        for wave in range(4):
            burst = keys[inserted:inserted + n_wave]
            sc.insert(burst,
                      np.arange(inserted, inserted + n_wave,
                                dtype=np.uint32))
            inserted += n_wave
            tv, sv = sc.versions()
            print(f"wave {wave}: burst of {n_wave} -> versions "
                  f"trad={tv} shortcut={sv} "
                  f"{'(STALE)' if sv < tv else ''}")
            for probe_i in range(3):
                probe = rng.choice(keys[:inserted], 20_000)
                route = "shortcut" if sc.use_shortcut() else "traditional"
                t0 = time.perf_counter()
                out = np.asarray(sc.lookup(probe))
                dt = (time.perf_counter() - t0) * 1e3
                assert (out != 0xFFFFFFFF).all()
                print(f"  lookup x20k via {route:11s}: {dt:6.1f} ms")
                time.sleep(0.01)
            sc.wait_in_sync()
            tv, sv = sc.versions()
            print(f"  resynced: trad={tv} shortcut={sv}; "
                  f"stats={sc.stats.creates}c/{sc.stats.updates}u")


if __name__ == "__main__":
    main()
