"""Quickstart: the paper's technique end-to-end in 60 lines.

Builds a Shortcut-EH index, shows the async maintenance / version gating /
fan-in routing cycle, and compares both access paths — then the same idea
one level up, on a paged KV cache.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.shortcut_eh import ShortcutEH


def main():
    rng = np.random.default_rng(0)
    keys = rng.choice(np.arange(1, 2**31, dtype=np.uint32), size=5000,
                      replace=False)
    vals = np.arange(5000, dtype=np.uint32)

    # the index: traditional directory (authoritative, synchronous) +
    # shortcut directory (async replica, hardware-friendly access path)
    with ShortcutEH(max_global_depth=12, bucket_slots=64, capacity=4096,
                    async_mapper=True) as index:
        index.insert(keys[:4000], vals[:4000])
        print(f"inserted 4000; versions (trad, shortcut) = "
              f"{index.versions()}  in_sync={index.in_sync()}")

        # lookups are correct immediately — routed via the traditional
        # path until the mapper catches up
        out = np.asarray(index.lookup(keys[:1000]))
        assert (out == vals[:1000]).all()
        print(f"lookup wave 1 ok; routed shortcut? "
              f"{index.routed_shortcut > 0}")

        index.wait_in_sync()
        print(f"mapper caught up; versions = {index.versions()}  "
              f"avg fan-in = {index.avg_fan_in():.2f}")

        out = np.asarray(index.lookup(keys[:4000]))
        assert (out == vals[:4000]).all()
        print(f"lookup wave 2 ok; routed shortcut? "
              f"{index.routed_shortcut > 0}")

        # an insert burst makes the shortcut stale again (Fig 8)
        index.insert(keys[4000:], vals[4000:])
        print(f"after burst: in_sync={index.in_sync()} "
              f"(lookups keep working via the traditional path)")
        out = np.asarray(index.lookup(keys))
        assert (out == vals).all()
        index.wait_in_sync()
        print(f"resynced: {index.versions()}; "
              f"maintenance stats: {index.stats}")


if __name__ == "__main__":
    main()
