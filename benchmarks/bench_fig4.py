"""Figure 4: impact of fan-in (slots per leaf).

Fixed directory width, varying number of distinct leaves: the shortcut
touches a view of ``slots`` pages regardless of fan-in while the
traditional path touches ``slots`` pointers + ``leaves`` pages — so high
fan-in favors the traditional path (the TLB-thrashing lesson; in the JAX
analogue the composed view's footprint is what grows).  Reproduction
target: a crossover — traditional wins at high fan-in, shortcut at low.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timeit
from repro.core import rewiring


def run(scale: float = 1.0 / 64):
    slots_log2 = max(12, int(np.log2(2 ** 22 * scale)))
    n_slots = 1 << slots_log2
    n_access = max(10_000, int(10_000_000 * scale))
    page_slots = 512  # 4KB page of int64 analogue: 512 u64 -> use u32 x512
    rng = np.random.default_rng(2)
    rows = []
    probe_slots = jnp.asarray(
        rng.integers(0, n_slots, n_access).astype(np.int32))

    for fan_in_log2 in (9, 6, 4, 2, 0):
        fan_in = 1 << fan_in_log2
        n_leaves = n_slots >> fan_in_log2
        pool = jnp.asarray(
            rng.integers(0, 2**31, (n_leaves, page_slots), np.int64)
            .astype(np.uint32))
        # directory: fan_in consecutive slots -> same leaf
        directory = jnp.asarray(
            (np.arange(n_slots) >> fan_in_log2).astype(np.int32))

        def traditional(slots):
            leaf = directory[slots]               # indirection 1
            return pool[leaf, slots % page_slots]  # indirection 2

        view = rewiring.compose(pool, directory)   # (n_slots, page)

        def shortcut(slots):
            return view[slots, slots % page_slots]

        t_trad = timeit(traditional, probe_slots) / n_access * 1e9
        t_short = timeit(shortcut, probe_slots) / n_access * 1e9
        rows += [
            Row("fig4", f"traditional_fanin_{fan_in}", t_trad,
                "ns/access", f"leaves={n_leaves}"),
            Row("fig4", f"shortcut_fanin_{fan_in}", t_short,
                "ns/access",
                f"ratio={t_trad / max(t_short, 1e-9):.2f}x"),
        ]
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
