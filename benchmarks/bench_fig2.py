"""Figure 2: lookup cost vs number of indexed leaf nodes, traditional
(two dependent indirections) vs shortcut (one).

The paper sweeps 2^8..2^21 4KB leaves under 10^7 uniform accesses; we
sweep a scaled range.  Reproduction target: the shortcut curve sits below
the traditional curve, and the gap grows with the directory size (random
gathers through an extra level dominate)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timeit, unique_keys
from repro.core import extendible_hashing as eh


def run(scale: float = 1.0 / 64):
    n_access = max(10_000, int(10_000_000 * scale))
    rng = np.random.default_rng(1)
    rows = []
    for leaves_log2 in (8, 10, 12, 14):
        n_keys = (1 << leaves_log2) * 2   # ~2 entries per 4-slot bucket
        keys = unique_keys(rng, n_keys)
        st = eh.eh_create(max_global_depth=leaves_log2 + 2,
                          bucket_slots=4, capacity=1 << (leaves_log2 + 1))
        st = eh.eh_insert_many(
            st, jnp.asarray(keys),
            jnp.asarray(np.arange(n_keys, dtype=np.uint32)))
        g = int(st.global_depth)
        vk, vv = eh.compose_shortcut(st, 1 << g)
        probe = jnp.asarray(rng.choice(keys, n_access))
        t_trad = timeit(eh.eh_lookup_many, st, probe) / n_access * 1e9
        t_short = timeit(eh.shortcut_lookup_many, vk, vv,
                         st.global_depth, probe) / n_access * 1e9
        rows += [
            Row("fig2", f"traditional_leaves_2^{leaves_log2}", t_trad,
                "ns/lookup", f"global_depth={g}"),
            Row("fig2", f"shortcut_leaves_2^{leaves_log2}", t_short,
                "ns/lookup", f"speedup={t_trad / max(t_short, 1e-9):.2f}x"),
        ]
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
