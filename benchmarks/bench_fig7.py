"""Figure 7: insertion (a) and lookup (b) across HT / HTI / CH / EH /
Shortcut-EH.

Paper: 100M inserts then 100M random hit-lookups; 4KB buckets; resize at
35% load.  Default scale 1/100.  Reproduction targets:
  7a — HT shows rehash staircases, HTI flattens them, EH/Shortcut-EH
       distribute resizing smoothly, CH is cheapest, and Shortcut-EH's
       maintenance overhead over EH is small (paper: ~8%);
  7b — Shortcut-EH ~ HT > EH > CH > HTI on lookups.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, sync, timeit, unique_keys
from repro.core import baselines as bl
from repro.core import extendible_hashing as eh
from repro.core.shortcut_eh import ShortcutEH


def run(scale: float = 1.0 / 100):
    n = max(20_000, int(100_000_000 * scale * 0.01))  # entries
    n_lookup = n
    batch = max(2_000, n // 16)
    rng = np.random.default_rng(4)
    keys = unique_keys(rng, n)
    vals = np.arange(n, dtype=np.uint32)
    probe = jnp.asarray(rng.choice(keys, n_lookup))
    rows = []
    max_log2 = int(np.ceil(np.log2(n / 0.30)))
    bucket_slots = 512  # 4KB of (k,v) u32 pairs

    def insert_curve(name, create, insert_many, lookup_many, state):
        t_accum = 0.0
        curve = []
        for i in range(0, n, batch):
            kb = jnp.asarray(keys[i:i + batch])
            vb = jnp.asarray(vals[i:i + batch])
            t0 = time.perf_counter()
            state = insert_many(state, kb, vb)
            sync(jax.tree.leaves(state)[0]) if hasattr(
                state, "_fields") else None
            t_accum += time.perf_counter() - t0
            curve.append(t_accum)
        rows.append(Row("fig7a", f"{name}_total_insert", t_accum, "s",
                        f"curve={['%.3f' % c for c in curve[::4]]}"))
        t_lk = timeit(lookup_many, state, probe) / n_lookup * 1e9
        rows.append(Row("fig7b", f"{name}_lookup", t_lk, "ns/lookup"))
        return state

    import jax
    # HT
    insert_curve("HT", None, bl.ht_insert_many, bl.ht_lookup_many,
                 bl.ht_create(max_log2, initial_size_log2=9))
    # HTI
    insert_curve("HTI", None, bl.hti_insert_many, bl.hti_lookup_many,
                 bl.hti_create(max_log2, initial_size_log2=9))
    # CH: fixed 1GB-analogue table (scaled), 128B buckets (16 pairs)
    insert_curve("CH", None, bl.ch_insert_many, bl.ch_lookup_many,
                 bl.ch_create(table_log2=max(8, max_log2 - 4),
                              capacity=max(n // 8, 1024),
                              bucket_slots=16))
    # EH
    eh_capacity = max(64, int(n / (bucket_slots * 0.3)) * 4)
    insert_curve("EH", None, eh.eh_insert_many, eh.eh_lookup_many,
                 eh.eh_create(max_global_depth=16,
                              bucket_slots=bucket_slots,
                              capacity=eh_capacity))

    # Shortcut-EH: synchronous inserts + async maintenance (pumped),
    # lookups routed per the version/fan-in gate
    sc = ShortcutEH(max_global_depth=16, bucket_slots=bucket_slots,
                    capacity=eh_capacity)
    t_accum = 0.0
    for i in range(0, n, batch):
        t0 = time.perf_counter()
        sc.insert(keys[i:i + batch], vals[i:i + batch])
        t_accum += time.perf_counter() - t0
    t_maint0 = time.perf_counter()
    sc.pump()
    t_maint = time.perf_counter() - t_maint0
    rows.append(Row("fig7a", "ShortcutEH_total_insert", t_accum, "s",
                    f"maintenance_async={t_maint:.3f}s"))
    assert sc.in_sync()
    t_lk = timeit(lambda p: sc.lookup(p), probe) / n_lookup * 1e9
    rows.append(Row("fig7b", "ShortcutEH_lookup", t_lk, "ns/lookup",
                    f"routed_shortcut={sc.use_shortcut()}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
