"""Sharded shortcut runtime: batched cross-shard lookup throughput vs N.

Builds a :class:`~repro.core.sharded_eh.ShardedShortcutEH` at N ∈
{1, 2, 4, 8} shards over the same key set, then measures

  * ``batched_lookup_NX``  — the fused cross-shard path (one argsort
    bucketize + ONE ``pallas_call`` whose grid iterates shards +
    scatter-back), end to end including the host partition pass;
  * ``routed_lookup_NX``   — the per-shard routed XLA path (each shard
    takes its own shortcut/traditional gate);
  * ``insert_NX``          — partitioned insert throughput (maintenance
    pumped outside the timed region, as in fig7's async accounting).

Reproduction target: throughput stays flat-to-rising with N (per-shard
structures shrink toward the VMEM-resident regime; on CPU/interpret the
curve mostly shows that cross-shard batching costs ~nothing), while
per-shard MaintenanceStats prove maintenance stayed shard-local.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, sync, timeit, unique_keys
from repro.core.sharded_eh import ShardedShortcutEH

SHARD_COUNTS = (1, 2, 4, 8)


def run(scale: float = 1.0 / 100):
    n = max(8_000, int(10_000_000 * scale * 0.05))
    rng = np.random.default_rng(11)
    keys = unique_keys(rng, n)
    vals = np.arange(n, dtype=np.uint32)
    probe = rng.choice(keys, n)
    bucket_slots = 64
    capacity = max(256, int(n / (bucket_slots * 0.3)) * 4)
    rows = []

    for N in SHARD_COUNTS:
        with ShardedShortcutEH(max_global_depth=14,
                               bucket_slots=bucket_slots,
                               capacity=capacity, num_shards=N) as idx:
            t0 = time.perf_counter()
            idx.insert(keys, vals)
            t_insert = time.perf_counter() - t0
            t0 = time.perf_counter()
            idx.pump()
            t_maint = time.perf_counter() - t0
            assert idx.in_sync()

            t_b = timeit(lambda: sync(idx.lookup_batched(probe)))
            t_r = timeit(lambda: sync(idx.lookup(probe)))
            per_shard = [(s.creates + s.updates)
                         for s in idx.per_shard_stats()]
            rows.append(Row("sharded", f"batched_lookup_N{N}",
                            n / t_b / 1e6, "Mkeys/s",
                            f"fan_in={idx.avg_fan_in():.2f}"))
            rows.append(Row("sharded", f"routed_lookup_N{N}",
                            n / t_r / 1e6, "Mkeys/s"))
            rows.append(Row("sharded", f"insert_N{N}",
                            n / t_insert / 1e6, "Minserts/s",
                            f"maintenance_async={t_maint:.3f}s"
                            f";replays_per_shard={per_shard}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
