"""Sharded shortcut runtime: batched cross-shard lookup throughput vs N,
and the device-resident operand cache vs the per-call restack baseline.

Builds a :class:`~repro.core.sharded_eh.ShardedShortcutEH` at N ∈
{1, 2, 4, 8} shards over the same key set, then measures

  * ``batched_lookup_NX``  — the fused cross-shard path (one argsort
    bucketize + ONE ``pallas_call`` whose grid iterates shards +
    scatter-back), end to end including the host partition pass; since
    the operand cache landed this is the *cached* path (zero dirty
    shards: no operand upload at all);
  * ``restack_lookup_NX``  — the pre-cache baseline reconstructed: the
    same kernel fed by a fresh ``jnp.stack`` of every shard's view on
    every call (the O(total index size) copy the cache deletes);
  * ``churn_lookup_NX_kK`` — the cache under write pressure: K of N
    shards are dirtied (one insert + pump each) between batches.  Since
    the zero-copy publish landed, the K slice patches ride the *pump*
    (mapper-side, before ``sc_version`` moves) and the lookup itself
    patches nothing — the bench asserts ``lookup_refreshes == 0`` after
    the sweep.  Reproduction target: degrades ≤ linearly in K, and K=N
    stays within ~the restack baseline (the publishes re-upload the
    same bytes the restack did, just off the read path);
  * ``routed_lookup_NX``   — the per-shard routed XLA path (each shard
    takes its own shortcut/traditional gate);
  * ``insert_NX``          — partitioned insert throughput (maintenance
    pumped outside the timed region, as in fig7's async accounting).

Reproduction target: ``batched`` ≥ ``restack`` everywhere, with the gap
widening as N (and total stacked bytes) grows — the lookup hot path now
pays O(changed shards) instead of O(index) per batch.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, sync, timeit, unique_keys
from repro.core.sharded_eh import ShardedShortcutEH, shard_of_keys
from repro.runtime.shard_group import pad_batch, partition_by_shard

SHARD_COUNTS = (1, 2, 4, 8)


def restack_lookup(idx: ShardedShortcutEH, keys: np.ndarray, *,
                   tile: int = 256):
    """The pre-cache batched path: bucketize, then ``jnp.stack`` every
    shard's composed view fresh and dispatch the shortcut kernel — the
    per-batch O(index) operand upload the cache eliminates.  (Assumes
    every shard is in sync with a composed view, which the bench
    guarantees; shape-uniform views for simplicity.)"""
    from repro.kernels.eh_lookup import sharded_shortcut_lookup
    keys = np.asarray(keys, np.uint32)
    sid = idx.shard_of(keys)
    cap = pad_batch(int(np.bincount(sid, minlength=idx.num_shards).max()))
    padded, counts, order, rank = partition_by_shard(
        keys, sid, idx.num_shards, cap)
    views = [s.view_snapshot() for s in idx.shards]
    v_cap = max(v[0].shape[0] for v in views)
    res = sharded_shortcut_lookup(
        jnp.asarray(padded),
        jnp.stack([jnp.pad(v[0], ((0, v_cap - v[0].shape[0]), (0, 0)))
                   for v in views]),
        jnp.stack([jnp.pad(v[1], ((0, v_cap - v[1].shape[0]), (0, 0)))
                   for v in views]),
        jnp.asarray([v[2] for v in views], jnp.int32), tile=tile)
    res = np.asarray(res)
    out = np.empty(keys.size, np.uint32)
    out[order] = res[sid[order], rank]
    return jnp.asarray(out)


def _churn_keys(rng, idx: ShardedShortcutEH, k: int):
    """One fresh key per target shard (the first k shards), to dirty
    exactly k of N shards per churn step."""
    out = []
    want = set(range(k))
    while want:
        cand = unique_keys(rng, 512, lo=2**30, hi=2**32 - 2)
        sid = shard_of_keys(cand, idx.shard_bits)
        for s in list(want):
            hit = cand[sid == s]
            if hit.size:
                out.append(int(hit[0]))
                want.discard(s)
    return out


def run(scale: float = 1.0 / 100):
    n = max(8_000, int(10_000_000 * scale * 0.05))
    rng = np.random.default_rng(11)
    keys = unique_keys(rng, n)
    vals = np.arange(n, dtype=np.uint32)
    probe = rng.choice(keys, n)
    bucket_slots = 64
    capacity = max(256, int(n / (bucket_slots * 0.3)) * 4)
    rows = []

    for N in SHARD_COUNTS:
        with ShardedShortcutEH(max_global_depth=14,
                               bucket_slots=bucket_slots,
                               capacity=capacity, num_shards=N) as idx:
            t0 = time.perf_counter()
            idx.insert(keys, vals)
            t_insert = time.perf_counter() - t0
            t0 = time.perf_counter()
            idx.pump()
            t_maint = time.perf_counter() - t0
            assert idx.in_sync()
            # pin the shortcut route: this sweep isolates operand
            # upload cost (cached vs restacked), not the §3.2 routing
            # law — at this scale fan-in crosses 8 around N=8 and would
            # silently flip the cached path onto the traditional kernel
            for s in idx.shards:
                s.fan_in_threshold = float("inf")

            # cached (zero dirty shards) vs per-call restack
            t_b = timeit(lambda: sync(idx.lookup_batched(probe)))
            cache = idx.operands.stats.snapshot()
            t_restack = timeit(lambda: sync(restack_lookup(idx, probe)))
            t_r = timeit(lambda: sync(idx.lookup(probe)))
            per_shard = [(s.creates + s.updates)
                         for s in idx.per_shard_stats()]
            rows.append(Row("sharded", f"batched_lookup_N{N}",
                            n / t_b / 1e6, "Mkeys/s",
                            f"fan_in={idx.avg_fan_in():.2f}"
                            f";cache_hits={cache.hits}"
                            f";publish_refreshes={cache.publish_refreshes}"
                            f";lookup_refreshes={cache.lookup_refreshes}"
                            f";rebuilds={cache.rebuilds}"))
            rows.append(Row("sharded", f"restack_lookup_N{N}",
                            n / t_restack / 1e6, "Mkeys/s"))
            # the headline invariant as its own strict-guarded row:
            # cached ≥ restack, i.e. speedup ≥ 1 ("x" = higher is better)
            rows.append(Row("sharded", f"cached_speedup_N{N}",
                            t_restack / t_b, "x"))
            resident = idx.operands.resident_bytes()
            rows.append(Row("sharded", f"operand_mib_N{N}",
                            sum(resident.values()) / 2**20, "MiB",
                            "double_buffered_equiv_mib="
                            f"{2 * sum(resident.values()) / 2**20:.3f}"
                            f";families={sorted(resident)}"))
            rows.append(Row("sharded", f"routed_lookup_N{N}",
                            n / t_r / 1e6, "Mkeys/s"))
            rows.append(Row("sharded", f"insert_N{N}",
                            n / t_insert / 1e6, "Minserts/s",
                            f"maintenance_async={t_maint:.3f}s"
                            f";replays_per_shard={per_shard}"))

            # replay churn: dirty k of N shards between batches; the
            # cached path pays k slice refreshes per lookup (its worst
            # case at k=N), the restack baseline always pays N
            for k in sorted({1, N}):
                churn = _churn_keys(rng, idx, k)
                cv = np.arange(len(churn), dtype=np.uint32)

                def dirty_then_lookup(fn):
                    idx.insert(np.asarray(churn, np.uint32), cv)
                    idx.pump()
                    return fn()

                t_c = timeit(lambda: sync(dirty_then_lookup(
                    lambda: idx.lookup_batched(probe))))
                t_cr = timeit(lambda: sync(dirty_then_lookup(
                    lambda: restack_lookup(idx, probe))))
                rows.append(Row("sharded", f"churn_lookup_N{N}_k{k}",
                                n / t_c / 1e6, "Mkeys/s",
                                f"restack_equiv={n / t_cr / 1e6:.3g}"
                                f";dirty={k}/{N}"))

            # the zero-copy contract, asserted: all churn above rode the
            # publish path (pump-side patches) — the lookup path never
            # issued a dynamic_update_slice
            final = idx.operands.stats
            assert final.lookup_refreshes == 0, (
                f"N={N}: {final.lookup_refreshes} slice patches leaked "
                f"onto the lookup path (publish-time refresh regressed)")
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
