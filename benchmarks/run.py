"""Benchmark harness entry point: one function per paper table/figure.

``python -m benchmarks.run [--scale S] [--only table1,fig2,...]
                           [--json PATH] [--compare PREV.json]
                           [--strict]``

Prints ``bench,name,value,unit,extra`` CSV rows; ``--json PATH``
additionally writes the full Row list as structured JSON
(``bench, name, value, unit, extra, wall``) — the machine-readable perf
trajectory CI archives per commit.  ``--compare PREV.json`` diffs the
run against a previous ``--json`` artifact and prints a WARNING for
every row regressed by more than 2x; with ``--strict`` those warnings
become a hard failure (exit code 3) — CI runs strict now that artifact
history exists (ROADMAP perf-trajectory phase 2).  A missing/unreadable
previous artifact never fails, strict or not (first run, expired
artifact).  The roofline table (§Roofline, from the multi-pod dry-run)
is appended when dry-run records exist under results/dryrun_baseline.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
import traceback

from benchmarks.common import Row, emit

ALL = ("table1", "fig2", "fig4", "fig5", "fig7", "fig8", "kv_shortcut",
       "sharded")

# Per-row strict-compare factors, keyed ``(bench, name)``; rows not
# listed use DEFAULT_FACTOR.  Calibrated from 4 repeated
# ``--scale 0.002`` runs on a single-core CI-class host: each bound is
# ~1.7x the observed max/min spread of its row.  Three bands:
#
#   * 1.3x  — spread stayed under ~12% (deterministic footprints, the
#     N>=4 churn/cached rows, the big fig7 insert walls);
#   * 1.5-1.7x — spread 12-35%;
#   * >2x   — rows whose spread already exceeded the old uniform 2.0
#     default (sub-second timings at N<=2, host-scheduling-bound pump
#     paths): a uniform 2.0 was silently flaky for these, so their
#     bounds are *loosened* to match measured reality.
#
# The replay_throughput_shards* rows pay a *deliberate* publish-side
# copy since the zero-copy lookup landed (the slice patch moved from
# the lookup path to the mapper thread) — do NOT tighten those below
# the default regardless of measured spread.
DEFAULT_FACTOR = 2.0
STRICT_FACTORS: dict = {
    # -- tight (1.3x): stable across runs ----------------------------------
    ("fig7a", "HT_total_insert"): 1.3,
    ("fig7a", "HTI_total_insert"): 1.3,
    ("fig7b", "CH_lookup"): 1.3,
    ("sharded", "insert_N1"): 1.3,
    ("sharded", "churn_lookup_N1_k1"): 1.3,
    ("sharded", "churn_lookup_N4_k1"): 1.3,
    ("sharded", "churn_lookup_N4_k4"): 1.3,
    ("sharded", "cached_speedup_N4"): 1.3,
    ("sharded", "operand_mib_N1"): 1.3,
    ("sharded", "operand_mib_N2"): 1.3,
    ("sharded", "operand_mib_N4"): 1.3,
    ("sharded", "operand_mib_N8"): 1.3,
    # -- mid (1.5-1.7x) ----------------------------------------------------
    ("fig7b", "HTI_lookup"): 1.5,
    ("fig7b", "HT_lookup"): 1.5,
    ("fig7b", "ShortcutEH_lookup"): 1.5,
    ("sharded", "batched_lookup_N4"): 1.5,
    ("sharded", "churn_lookup_N8_k8"): 1.5,
    ("sharded", "churn_lookup_N2_k1"): 1.5,
    ("sharded", "restack_lookup_N4"): 1.5,
    ("fig7b", "EH_lookup"): 1.7,
    ("fig7a", "ShortcutEH_total_insert"): 1.7,
    ("fig7a", "CH_total_insert"): 1.7,
    ("fig7a", "EH_total_insert"): 1.7,
    ("kv_shortcut", "compose_view_all_seqs"): 1.7,
    ("sharded", "batched_lookup_N8"): 1.7,
    ("sharded", "cached_speedup_N2"): 1.7,
    ("sharded", "cached_speedup_N8"): 1.7,
    ("sharded", "insert_N8"): 1.7,
    ("sharded", "restack_lookup_N8"): 1.7,
    ("sharded", "routed_lookup_N4"): 1.7,
    # -- looser than the old default (measured spread > ~1.65x) ------------
    ("kv_shortcut", "append_update_request"): 2.8,
    ("kv_shortcut", "paged_gather_context"): 2.8,
    ("kv_shortcut", "shortcut_slice_raw"): 2.8,
    ("sharded", "churn_lookup_N2_k2"): 2.8,
    ("kv_shortcut", "replay_throughput_shards1"): 3.5,
    ("kv_shortcut", "shortcut_slice_context"): 3.5,
    ("sharded", "batched_lookup_N2"): 3.5,
    ("sharded", "churn_lookup_N8_k1"): 3.5,
    ("sharded", "insert_N4"): 3.5,
    ("sharded", "restack_lookup_N1"): 3.5,
    ("sharded", "restack_lookup_N2"): 3.5,
    ("kv_shortcut", "paged_gather_raw"): 4.0,
    ("sharded", "insert_N2"): 4.5,
    ("kv_shortcut", "replay_throughput_shards2"): 6.0,
    ("sharded", "batched_lookup_N1"): 6.0,
    ("sharded", "routed_lookup_N2"): 8.0,
    ("sharded", "cached_speedup_N1"): 10.0,
}


def _strict_factor(bench: str, name: str) -> float:
    return STRICT_FACTORS.get((bench, name), DEFAULT_FACTOR)


def _regression_ratio(row: Row, prev: dict) -> float:
    """How many times worse ``row`` is than ``prev`` (1.0 = unchanged);
    0.0 for rows whose unit encodes no better/worse direction."""
    cur_v, prev_v = float(row.value), float(prev["value"])
    if cur_v <= 0 or prev_v <= 0:
        return 0.0
    base = row.unit.split("/")[0]
    if base in ("s", "ms", "us", "ns"):       # time-like: lower is better
        return cur_v / prev_v
    if base in ("B", "KiB", "MiB", "GiB"):    # footprint: lower is better
        return cur_v / prev_v
    if row.unit.endswith("/s"):               # throughput: higher is better
        return prev_v / cur_v
    if row.unit == "x":                       # speedup ratio: higher is better
        return prev_v / cur_v
    return 0.0


def compare_to_previous(rows: list, prev_path: str,
                        factor: float = None, strict: bool = False) -> int:
    """Print a WARNING per row regressed past its per-row factor
    (``STRICT_FACTORS``, default ``DEFAULT_FACTOR``) vs the previous
    ``--json`` artifact; returns the number of warnings (``main`` turns
    a nonzero count into exit code 3 under ``--strict``).  Passing
    ``factor`` overrides the table for every row (tests use this).  A
    missing or unreadable artifact is a note, not an error (first run,
    expired artifact) — strict mode only fails on *measured*
    regressions."""
    try:
        with open(prev_path) as f:
            prev_rows = json.load(f)
    except (OSError, ValueError) as e:
        print(f"compare: no usable previous artifact at {prev_path} "
              f"({e}); skipping perf diff", file=sys.stderr)
        return 0
    prev = {(r["bench"], r["name"]): r for r in prev_rows}
    warned = 0
    for r in rows:
        if r.name.startswith("_"):            # _bench_wall / _bench_error
            continue
        p = prev.get((r.bench, r.name))
        if p is None or p.get("unit") != r.unit:
            continue
        row_factor = (factor if factor is not None
                      else _strict_factor(r.bench, r.name))
        ratio = _regression_ratio(r, p)
        if ratio > row_factor:
            warned += 1
            print(f"WARNING: perf regression {r.bench},{r.name}: "
                  f"{p['value']:.6g} -> {r.value:.6g} {r.unit} "
                  f"({ratio:.2f}x worse, bound {row_factor:.2f}x)",
                  file=sys.stderr)
    if warned:
        print(f"compare: {warned} row(s) regressed past their bound vs "
              f"{prev_path} "
              f"({'FAILING (--strict)' if strict else 'warning only'})",
              file=sys.stderr)
    else:
        print(f"compare: no regressions past per-row bounds vs "
              f"{prev_path}", file=sys.stderr)
    return warned


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0 / 100,
                    help="fraction of paper-size workloads (1.0 = paper)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of: " + ",".join(ALL))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write all rows as structured JSON to PATH")
    ap.add_argument("--compare", default=None, metavar="PREV.json",
                    help="diff against a previous --json artifact and "
                         "warn on >2x regressions")
    ap.add_argument("--strict", action="store_true",
                    help="with --compare: exit 3 when any row regressed "
                         ">2x (a missing previous artifact still passes)")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args(argv)
    wanted = [b for b in args.only.split(",") if b] or list(ALL)

    rows: list = []
    failures = 0
    for name in wanted:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        try:
            bench_rows = mod.run(scale=args.scale)
            wall = time.time() - t0
            for r in bench_rows:
                r.wall = wall
            rows += bench_rows
            rows.append(Row(name, "_bench_wall", wall, "s", wall=wall))
        except Exception as e:
            failures += 1
            rows.append(Row(name, "_bench_error", 0.0, "-",
                            f"{type(e).__name__}: {e}",
                            wall=time.time() - t0))
            traceback.print_exc(file=sys.stderr)
    emit(rows)

    if args.json:
        with open(args.json, "w") as f:
            json.dump([dataclasses.asdict(r) for r in rows], f, indent=2)
            f.write("\n")
        print(f"wrote {len(rows)} rows to {args.json}", file=sys.stderr)

    regressions = 0
    if args.compare:
        regressions = compare_to_previous(rows, args.compare,
                                          strict=args.strict)

    if not args.skip_roofline:
        import os
        for d in ("results/dryrun_final", "results/dryrun_baseline"):
            if os.path.isdir(d):
                from benchmarks import roofline
                print(f"\n== Roofline (from multi-pod dry-run: {d}) ==")
                roofline.main(["--dir", d])
                break
    if failures:
        return 1
    if args.strict and regressions:
        return 3
    return 0


if __name__ == "__main__":
    sys.exit(main())
