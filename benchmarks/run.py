"""Benchmark harness entry point: one function per paper table/figure.

``python -m benchmarks.run [--scale S] [--only table1,fig2,...]
                           [--json PATH]``

Prints ``bench,name,value,unit,extra`` CSV rows; ``--json PATH``
additionally writes the full Row list as structured JSON
(``bench, name, value, unit, extra, wall``) — the machine-readable perf
trajectory CI archives per commit.  The roofline table (§Roofline, from
the multi-pod dry-run) is appended when dry-run records exist under
results/dryrun_baseline.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
import traceback

from benchmarks.common import Row, emit

ALL = ("table1", "fig2", "fig4", "fig5", "fig7", "fig8", "kv_shortcut",
       "sharded")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0 / 100,
                    help="fraction of paper-size workloads (1.0 = paper)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of: " + ",".join(ALL))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write all rows as structured JSON to PATH")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args(argv)
    wanted = [b for b in args.only.split(",") if b] or list(ALL)

    rows: list = []
    failures = 0
    for name in wanted:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        try:
            bench_rows = mod.run(scale=args.scale)
            wall = time.time() - t0
            for r in bench_rows:
                r.wall = wall
            rows += bench_rows
            rows.append(Row(name, "_bench_wall", wall, "s", wall=wall))
        except Exception as e:
            failures += 1
            rows.append(Row(name, "_bench_error", 0.0, "-",
                            f"{type(e).__name__}: {e}",
                            wall=time.time() - t0))
            traceback.print_exc(file=sys.stderr)
    emit(rows)

    if args.json:
        with open(args.json, "w") as f:
            json.dump([dataclasses.asdict(r) for r in rows], f, indent=2)
            f.write("\n")
        print(f"wrote {len(rows)} rows to {args.json}", file=sys.stderr)

    if not args.skip_roofline:
        import os
        for d in ("results/dryrun_final", "results/dryrun_baseline"):
            if os.path.isdir(d):
                from benchmarks import roofline
                print(f"\n== Roofline (from multi-pod dry-run: {d}) ==")
                roofline.main(["--dir", d])
                break
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
