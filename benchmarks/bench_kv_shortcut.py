"""Beyond-paper: the serving-layer shortcut (paged vs contiguous KV view).

Measures one decode step's context access on CPU at reduced scale:
  paged     — block-table gather (two dependent indirections)
  shortcut  — contiguous view slice (address arithmetic)
plus the maintenance cost of keeping the view in sync (the async replay),
mirroring Table 1's economics at the KV-cache layer.  The TPU-scale
version of this comparison is the dry-run roofline delta
(EXPERIMENTS.md §Perf, decode cells).

The ``--num-shards`` sweep measures **replay throughput** of the
per-shard view arrays (DESIGN.md §4.2): N shard replay threads drain the
same append workload, once through the lock-free per-shard manager and
once through a reconstruction of the pre-sharding arrangement (ONE
whole-batch view pair, every read-modify-write serialized on one global
view lock) — the scaling-vs-locked-baseline curve of the removed lock.
"""
from __future__ import annotations

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, sync, timeit
from repro.kvcache import paged_cache as pc
from repro.kvcache.shortcut_cache import (ShortcutKVManager, append_to_view,
                                          compose_seq, slice_context)


def _impose_locked_baseline(mgr, n_seqs: int, cap: int) -> None:
    """Reconstruct the pre-sharding arrangement on a live manager: ONE
    whole-batch (view_k, view_v) pair shared by every shard's replay,
    each read-modify-write serialized on a single global view lock (and
    copying the full whole-batch arrays, not a shard's slice) — exactly
    what the per-shard registry replaced."""
    L, _, _, KV, hd = mgr.cache.k_pool.shape
    state = {"views": (jnp.zeros((L, n_seqs, cap, KV, hd), jnp.float32),
                       jnp.zeros((L, n_seqs, cap, KV, hd), jnp.float32))}
    view_lock = threading.Lock()

    def replay_create(snap, reqs, shard):
        with view_lock:
            vk, vv = state["views"]
            for r in reqs:
                for s in np.asarray(r.payload):
                    vk, vv = compose_seq(snap, vk, vv, jnp.int32(int(s)),
                                         jnp.int32(int(s)))
            state["views"] = (vk, vv)

    def replay_update(snap, reqs, shard):
        with view_lock:
            vk, vv = state["views"]
            for r in reqs:
                seq_ids, positions, nk, nv = r.payload
                vk, vv = append_to_view(vk, vv, jnp.asarray(seq_ids),
                                        jnp.asarray(positions), nk, nv)
            state["views"] = (vk, vv)

    for i, m in enumerate(mgr.group):
        m._replay_create = lambda snap, reqs, shard=i: \
            replay_create(snap, reqs, shard)
        m._replay_update = lambda snap, reqs, shard=i: \
            replay_update(snap, reqs, shard)
        m._view_arrays = lambda: state["views"]


def replay_throughput(num_shards: int, *, n_seqs: int = 32,
                      appends: int = 32, kv_heads: int = 2,
                      head_dim: int = 128, rounds: int = 3,
                      locked_baseline: bool = False) -> float:
    """Token rows replayed per second with one pump thread per shard
    (median over ``rounds`` enqueue+drain cycles).

    ``locked_baseline=True`` measures the identical workload through the
    pre-sharding replay path (:func:`_impose_locked_baseline`); the pair
    isolates what the per-shard split buys — no serialization AND
    1/N-sized copies per replay."""
    bs = 4
    cap = -(-(bs + rounds * appends + 2) // bs) * bs
    rng = np.random.default_rng(7)
    cache = pc.cache_create(2, n_seqs * (cap // bs) * 2, bs, kv_heads,
                            head_dim, n_seqs, cap // bs,
                            dtype=jnp.float32)
    with ShortcutKVManager(cache, seq_capacity=cap,
                           num_shards=num_shards) as mgr:
        if locked_baseline:
            _impose_locked_baseline(mgr, n_seqs, cap)
        k = jnp.asarray(rng.normal(
            size=(2, n_seqs, bs, kv_heads, head_dim)).astype(np.float32))
        mgr.prefill(np.arange(n_seqs), k, -k)
        mgr.pump()
        all_ids = np.arange(n_seqs)
        nk = jnp.asarray(rng.normal(
            size=(2, n_seqs, kv_heads, head_dim)).astype(np.float32))
        mgr.append(all_ids, nk, -nk)     # warm the jit variants
        mgr.pump()
        rates = []
        for _ in range(rounds):
            for _ in range(appends):
                mgr.append(all_ids, nk, -nk)
            threads = [threading.Thread(target=mgr.group[s].pump)
                       for s in range(num_shards)]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            rates.append(appends * n_seqs / (time.perf_counter() - t0))
    return float(np.median(rates))


def replay_scaling_rows(scale: float, num_shards=(1, 2, 4)) -> list:
    appends = max(8, int(2048 * scale))
    rows = []
    for n in num_shards:
        tp = replay_throughput(n, appends=appends)
        locked = replay_throughput(n, appends=appends,
                                   locked_baseline=True)
        rows.append(Row(
            "kv_shortcut", f"replay_throughput_shards{n}", tp, "rows/s",
            f"lock-free per-shard views; locked 1-view baseline "
            f"{locked:.0f} rows/s ({tp / max(locked, 1e-9):.2f}x)"))
    return rows


def run(scale: float = 1.0 / 64, num_shards=(1, 2, 4)):
    L, KV, hd, bs = 4, 4, 64, 16
    B = 8
    S = max(256, int(32768 * scale * 4))
    S = -(-S // bs) * bs            # block-aligned
    nblocks = B * (S // bs) * 2
    rng = np.random.default_rng(6)
    rows = []

    cache = pc.cache_create(L, nblocks, bs, KV, hd, max_seqs=B,
                            max_blocks_per_seq=S // bs,
                            dtype=jnp.float32)
    k = jnp.asarray(rng.normal(size=(L, B, S, KV, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(L, B, S, KV, hd)).astype(np.float32))
    seq_ids = jnp.arange(B)
    cache = pc.write_prefill(cache, seq_ids, k, v)
    # fragment the block tables: shuffle logical->physical (the realistic
    # post-eviction state the paper's fan-in lesson maps to)
    tables = np.array(cache.block_tables)  # writable host copy
    for b in range(B):
        perm = rng.permutation(S // bs)
        tables[b, :S // bs] = tables[b, :S // bs][perm]
    # keep pool content consistent with the shuffled tables (content
    # equality is tested elsewhere; here we only measure access cost)
    cache = cache._replace(block_tables=jnp.asarray(tables))

    t_paged = timeit(pc.gather_context, cache, seq_ids) * 1e3
    rows.append(Row("kv_shortcut", "paged_gather_context", t_paged,
                    "ms/step", f"B={B} S={S} (incl. layout transform)"))

    # raw indirection cost, storage layout (no attention-layout transform
    # — on CPU the transform dominates both paths and hides the gap)
    import jax
    @jax.jit
    def paged_raw(cache, seq_ids):
        tables = cache.block_tables[seq_ids]
        safe = jnp.maximum(tables, 0)
        return cache.k_pool[:, safe], cache.v_pool[:, safe]

    t_paged_raw = timeit(paged_raw, cache, seq_ids) * 1e3
    rows.append(Row("kv_shortcut", "paged_gather_raw", t_paged_raw,
                    "ms/step", "two dependent indirections"))

    # compose the shortcut view (create request) — the maintenance cost
    view_k = jnp.zeros((L, B, S, KV, hd), jnp.float32)
    view_v = jnp.zeros_like(view_k)
    t0 = time.perf_counter()
    for s in range(B):
        view_k, view_v = compose_seq(cache, view_k, view_v, jnp.int32(s),
                                     jnp.int32(s))
    sync(view_k)
    t_compose = (time.perf_counter() - t0) * 1e3
    rows.append(Row("kv_shortcut", "compose_view_all_seqs", t_compose,
                    "ms", "the create-request replay (async in prod)"))

    t_short = timeit(slice_context, view_k, view_v, seq_ids) * 1e3
    rows.append(Row("kv_shortcut", "shortcut_slice_context", t_short,
                    "ms/step",
                    f"speedup={t_paged / max(t_short, 1e-9):.2f}x "
                    "(incl. layout transform)"))

    @jax.jit
    def short_raw(view_k, view_v, seq_ids):
        return view_k[:, seq_ids], view_v[:, seq_ids]

    t_short_raw = timeit(short_raw, view_k, view_v, seq_ids) * 1e3
    rows.append(Row("kv_shortcut", "shortcut_slice_raw", t_short_raw,
                    "ms/step",
                    f"speedup={t_paged_raw / max(t_short_raw, 1e-9):.2f}x"
                    " (pure indirection cost)"))

    # per-token append maintenance (update request)
    nk = jnp.asarray(rng.normal(size=(L, B, KV, hd)).astype(np.float32))
    nv = jnp.asarray(rng.normal(size=(L, B, KV, hd)).astype(np.float32))
    pos = jnp.full((B,), S - 1, jnp.int32)
    t_append = timeit(append_to_view, view_k, view_v, seq_ids, pos,
                      nk, nv) * 1e6
    rows.append(Row("kv_shortcut", "append_update_request", t_append,
                    "us/step", "per-decode-token view maintenance"))

    # replay throughput: lock-free per-shard views vs the locked
    # whole-batch baseline, per shard count
    rows += replay_scaling_rows(scale, num_shards)
    return rows


if __name__ == "__main__":
    import argparse
    from benchmarks.common import emit
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0 / 64)
    ap.add_argument("--num-shards", default="1,2,4",
                    help="comma-separated shard counts for the replay "
                         "throughput sweep")
    args = ap.parse_args()
    emit(run(scale=args.scale,
             num_shards=tuple(int(x) for x in args.num_shards.split(","))))
