"""Beyond-paper: the serving-layer shortcut (paged vs contiguous KV view).

Measures one decode step's context access on CPU at reduced scale:
  paged     — block-table gather (two dependent indirections)
  shortcut  — contiguous view slice (address arithmetic)
plus the maintenance cost of keeping the view in sync (the async replay),
mirroring Table 1's economics at the KV-cache layer.  The TPU-scale
version of this comparison is the dry-run roofline delta
(EXPERIMENTS.md §Perf, decode cells).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, sync, timeit
from repro.kvcache import paged_cache as pc
from repro.kvcache.shortcut_cache import (ShortcutKVManager, compose_seq,
                                          slice_context)


def run(scale: float = 1.0 / 64):
    L, KV, hd, bs = 4, 4, 64, 16
    B = 8
    S = max(256, int(32768 * scale * 4))
    S = -(-S // bs) * bs            # block-aligned
    nblocks = B * (S // bs) * 2
    rng = np.random.default_rng(6)
    rows = []

    cache = pc.cache_create(L, nblocks, bs, KV, hd, max_seqs=B,
                            max_blocks_per_seq=S // bs,
                            dtype=jnp.float32)
    k = jnp.asarray(rng.normal(size=(L, B, S, KV, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(L, B, S, KV, hd)).astype(np.float32))
    seq_ids = jnp.arange(B)
    cache = pc.write_prefill(cache, seq_ids, k, v)
    # fragment the block tables: shuffle logical->physical (the realistic
    # post-eviction state the paper's fan-in lesson maps to)
    tables = np.array(cache.block_tables)  # writable host copy
    for b in range(B):
        perm = rng.permutation(S // bs)
        tables[b, :S // bs] = tables[b, :S // bs][perm]
    # keep pool content consistent with the shuffled tables (content
    # equality is tested elsewhere; here we only measure access cost)
    cache = cache._replace(block_tables=jnp.asarray(tables))

    t_paged = timeit(pc.gather_context, cache, seq_ids) * 1e3
    rows.append(Row("kv_shortcut", "paged_gather_context", t_paged,
                    "ms/step", f"B={B} S={S} (incl. layout transform)"))

    # raw indirection cost, storage layout (no attention-layout transform
    # — on CPU the transform dominates both paths and hides the gap)
    import jax
    @jax.jit
    def paged_raw(cache, seq_ids):
        tables = cache.block_tables[seq_ids]
        safe = jnp.maximum(tables, 0)
        return cache.k_pool[:, safe], cache.v_pool[:, safe]

    t_paged_raw = timeit(paged_raw, cache, seq_ids) * 1e3
    rows.append(Row("kv_shortcut", "paged_gather_raw", t_paged_raw,
                    "ms/step", "two dependent indirections"))

    # compose the shortcut view (create request) — the maintenance cost
    view_k = jnp.zeros((L, B, S, KV, hd), jnp.float32)
    view_v = jnp.zeros_like(view_k)
    t0 = time.perf_counter()
    for s in range(B):
        view_k, view_v = compose_seq(cache, view_k, view_v, jnp.int32(s))
    sync(view_k)
    t_compose = (time.perf_counter() - t0) * 1e3
    rows.append(Row("kv_shortcut", "compose_view_all_seqs", t_compose,
                    "ms", "the create-request replay (async in prod)"))

    t_short = timeit(slice_context, view_k, view_v, seq_ids) * 1e3
    rows.append(Row("kv_shortcut", "shortcut_slice_context", t_short,
                    "ms/step",
                    f"speedup={t_paged / max(t_short, 1e-9):.2f}x "
                    "(incl. layout transform)"))

    @jax.jit
    def short_raw(view_k, view_v, seq_ids):
        return view_k[:, seq_ids], view_v[:, seq_ids]

    t_short_raw = timeit(short_raw, view_k, view_v, seq_ids) * 1e3
    rows.append(Row("kv_shortcut", "shortcut_slice_raw", t_short_raw,
                    "ms/step",
                    f"speedup={t_paged_raw / max(t_short_raw, 1e-9):.2f}x"
                    " (pure indirection cost)"))

    # per-token append maintenance (update request)
    nk = jnp.asarray(rng.normal(size=(L, B, KV, hd)).astype(np.float32))
    nv = jnp.asarray(rng.normal(size=(L, B, KV, hd)).astype(np.float32))
    from repro.kvcache.shortcut_cache import append_to_view
    pos = jnp.full((B,), S - 1, jnp.int32)
    t_append = timeit(append_to_view, view_k, view_v, seq_ids, pos,
                      nk, nv) * 1e6
    rows.append(Row("kv_shortcut", "append_update_request", t_append,
                    "us/step", "per-decode-token view maintenance"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
