"""Figure 5: maintenance/reader interference (the TLB-shootdown analogue).

Paper setup: a "shooting" thread remaps pages while reader threads scan;
shootdown cost lands on the shooter, not the readers.  TPU/JAX analogue
(DESIGN.md §2): view re-materialization competes for HBM bandwidth /
dispatch with readers.  We run a mapper thread replaying remap batches
against the composed view while reader threads run batched lookups, and
report (a) per-remap cost vs reader count, (b) per-read cost with the
mapper active, (c) per-read cost without it.

Reproduction target: remap cost grows with concurrent readers; reader
cost stays roughly flat (maintenance hides on the maintenance thread).
"""
from __future__ import annotations

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, sync
from repro.core import rewiring


def run(scale: float = 1.0 / 64):
    n_slots = 1 << max(12, int(np.log2(2 ** 20 * scale)))
    page = 256
    n_remaps = max(200, int(2 ** 19 * scale * 0.05))
    reads_per_wave = 200_000
    rng = np.random.default_rng(3)
    pool = jnp.asarray(rng.integers(0, 2**31, (n_slots, page), np.int64)
                       .astype(np.uint32))
    view0 = rewiring.compose(
        pool, jnp.arange(n_slots, dtype=jnp.int32))
    sync(view0)
    probe = jnp.asarray(rng.integers(0, n_slots, reads_per_wave)
                        .astype(np.int32))

    def read_wave(view):
        return view[probe, probe % page].sum()

    rows = []
    for n_readers in (0, 1, 2, 4):
        stop = threading.Event()
        read_counts = [0] * max(n_readers, 1)
        read_times = [0.0] * max(n_readers, 1)

        def reader(i):
            local_view = view0
            while not stop.is_set():
                t0 = time.perf_counter()
                sync(read_wave(local_view))
                read_times[i] += time.perf_counter() - t0
                read_counts[i] += 1

        threads = [threading.Thread(target=reader, args=(i,), daemon=True)
                   for i in range(n_readers)]
        for t in threads:
            t.start()
        # the shooter: replay remap batches
        view = view0
        slots = jnp.asarray(rng.integers(0, n_slots, 64).astype(np.int32))
        offs = jnp.asarray(rng.integers(0, n_slots, 64).astype(np.int32))
        t0 = time.perf_counter()
        for _ in range(n_remaps // 64):
            view = rewiring.remap_slots(view, pool, slots, offs)
        sync(view)
        t_remap = (time.perf_counter() - t0) / n_remaps * 1e6
        stop.set()
        for t in threads:
            t.join(timeout=5)
        rows.append(Row("fig5", f"remap_with_{n_readers}_readers",
                        t_remap, "us/remap"))
        if n_readers:
            per_read = sum(read_times) / max(sum(read_counts), 1) \
                / reads_per_wave * 1e9
            rows.append(Row("fig5", f"read_during_remap_{n_readers}",
                            per_read, "ns/read"))

    # baseline reader cost without a shooter
    t0 = time.perf_counter()
    for _ in range(5):
        sync(read_wave(view0))
    rows.append(Row("fig5", "read_no_shooter",
                    (time.perf_counter() - t0) / 5 / reads_per_wave * 1e9,
                    "ns/read"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
