"""Shared benchmark utilities.

Scale note (DESIGN.md §7): the paper runs 100M inserts / 10M probes on a
12700KF; this container is one CPU core running JAX, so benches default to
1/64--1/100 scale. ``--scale 1.0`` restores paper sizes. The reproduction
target is the SHAPE of each curve (staircase HT, graceful EH, shortcut
crossover), with absolute times reported for this machine.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import numpy as np


def sync(x):
    jax.block_until_ready(x)
    return x


def timeit(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    """Median wall seconds of fn(*args) with device sync."""
    for _ in range(warmup):
        sync(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        sync(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def unique_keys(rng, n, lo=1, hi=2**31):
    if n > (hi - lo) // 2:
        raise ValueError("key space too small")
    return rng.choice(np.arange(lo, hi, dtype=np.uint32), size=n,
                      replace=False)


@dataclass
class Row:
    bench: str
    name: str
    value: float
    unit: str
    extra: str = ""
    wall: float = 0.0   # wall seconds of the whole bench run (set by run.py)

    def csv(self) -> str:
        return f"{self.bench},{self.name},{self.value:.6g},{self.unit}," \
            f"{self.extra}"


def emit(rows):
    print("bench,name,value,unit,extra")
    for r in rows:
        print(r.csv())
