"""Figure 8: synchronization under a mixed workload.

Paper: bulk-load 92M, then four waves of 2M accesses (1% inserts then 99%
lookups), plotting lookup latency + both version numbers — the shortcut
goes stale during each insert burst, lookups fall back to the traditional
directory, and the mapper catches up shortly after.

Here the mapper runs as a real async thread; we sample lookup latency and
versions through the waves.  Reproduction target: lookup time spikes
during the burst (traditional routing) and drops below the EH baseline
once versions re-converge."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, sync, unique_keys
from repro.core import extendible_hashing as eh
from repro.core.shortcut_eh import ShortcutEH


def run(scale: float = 1.0 / 100):
    n_bulk = max(20_000, int(92_000_000 * scale * 0.01))
    wave_inserts = max(400, n_bulk // 50)
    wave_lookups = 12
    lookup_batch = max(5_000, n_bulk // 4)
    rng = np.random.default_rng(5)
    keys = unique_keys(rng, n_bulk + 4 * wave_inserts)
    bucket_slots = 512
    capacity = max(64, int(n_bulk / (bucket_slots * 0.25)) * 8)

    rows = []
    with ShortcutEH(max_global_depth=16, bucket_slots=bucket_slots,
                    capacity=capacity, poll_interval=0.002,
                    async_mapper=True) as sc:
        sc.insert(keys[:n_bulk], np.arange(n_bulk, dtype=np.uint32))
        sc.wait_in_sync()
        # EH baseline for comparison: same state, always traditional
        probe = jnp.asarray(rng.choice(keys[:n_bulk], lookup_batch))
        t0 = time.perf_counter()
        sync(eh.eh_lookup_many(sc.state, probe))
        t_eh = (time.perf_counter() - t0) / lookup_batch * 1e9
        rows.append(Row("fig8", "EH_baseline_lookup", t_eh, "ns/lookup"))

        inserted = n_bulk
        for wave in range(4):
            burst = keys[inserted:inserted + wave_inserts]
            sc.insert(burst, np.arange(inserted, inserted + wave_inserts,
                                       dtype=np.uint32))
            inserted += wave_inserts
            stale_seen = not sc.in_sync()
            # lookups while (possibly) out of sync
            lat = []
            routes_sc = 0
            for i in range(wave_lookups):
                probe = jnp.asarray(
                    rng.choice(keys[:inserted], lookup_batch))
                used_shortcut = sc.use_shortcut()
                t0 = time.perf_counter()
                sync(sc.lookup(probe))
                lat.append((time.perf_counter() - t0)
                           / lookup_batch * 1e9)
                routes_sc += int(used_shortcut)
            tv, sv = sc.versions()
            rows.append(Row(
                "fig8", f"wave{wave}_lookup_mean",
                float(np.mean(lat)), "ns/lookup",
                f"stale_at_burst={stale_seen} shortcut_routed="
                f"{routes_sc}/{wave_lookups} versions={tv}/{sv}"))
            sc.wait_in_sync()
            probe = jnp.asarray(rng.choice(keys[:inserted], lookup_batch))
            t0 = time.perf_counter()
            sync(sc.lookup(probe))
            rows.append(Row(
                "fig8", f"wave{wave}_lookup_after_sync",
                (time.perf_counter() - t0) / lookup_batch * 1e9,
                "ns/lookup", f"in_sync={sc.in_sync()}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
