"""Roofline aggregation: dry-run JSON records -> the §Roofline table.

Reads ``results/<dir>/*.json`` produced by ``repro.launch.dryrun`` and
emits the per-(arch x shape x mesh) table with the three terms, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and a one-line "what would
move the dominant term" note derived from the collective/byte mix.

Usage: python -m benchmarks.roofline [--dir results/dryrun_baseline]
       [--format md|csv]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def advice(rec: dict) -> str:
    dom = rec.get("dominant")
    coll = rec.get("collectives", {}).get("bytes", {})
    if dom == "collective_s":
        top = max(coll, key=coll.get) if coll else "?"
        return f"cut {top} volume (resharding/dtype/overlap)"
    if dom == "memory_s":
        if rec["shape"].startswith(("decode", "long")):
            return "stream cache once (Pallas decode kernel), drop " \
                   "f32 round-trips"
        return "fuse attention interior (Pallas flash), bf16 " \
               "intermediates, selective remat"
    return "increase arithmetic intensity (larger tiles/batch)"


def load(dirpath: str) -> list:
    recs = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def table(recs: list, fmt: str = "md") -> str:
    hdr = ["arch", "shape", "mesh", "path", "compute_s", "memory_s",
           "collective_s", "dominant", "useful_ratio", "roofline_frac",
           "mem_GiB/dev", "next_move"]
    lines = []
    if fmt == "md":
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    else:
        lines.append(",".join(hdr))
    for r in recs:
        if r.get("status") == "skip":
            row = [r["arch"], r["shape"], r["mesh"],
                   r.get("path", "-"), "SKIP", "-", "-", "-", "-", "-",
                   "-", r.get("reason", "")[:40]]
        elif r.get("status") == "error":
            row = [r["arch"], r["shape"], r["mesh"],
                   r.get("path", "-"), "ERROR", "-", "-", "-", "-", "-",
                   "-", r.get("error", "")[:40]]
        else:
            row = [
                r["arch"], r["shape"], r["mesh"], r.get("path", "-"),
                f"{r['compute_s']:.4f}", f"{r['memory_s']:.4f}",
                f"{r['collective_s']:.4f}",
                r["dominant"].replace("_s", ""),
                f"{r['useful_flops_ratio']:.3f}",
                f"{r['roofline_fraction']:.3f}",
                f"{r['memory']['total_bytes'] / 2**30:.1f}",
                advice(r),
            ]
        if fmt == "md":
            lines.append("| " + " | ".join(str(c) for c in row) + " |")
        else:
            lines.append(",".join(str(c) for c in row))
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun_baseline")
    ap.add_argument("--format", choices=["md", "csv"], default="md")
    args = ap.parse_args(argv)
    recs = load(args.dir)
    if not recs:
        print(f"no records in {args.dir} — run "
              "`python -m repro.launch.dryrun --arch all --shape all "
              f"--out {args.dir}` first")
        return 1
    print(table(recs, args.format))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
