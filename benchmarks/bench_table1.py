"""Table 1: normalized cost of creating and then randomly accessing a
wide inner node (paper: 2^22 slots, 4KB leaves; default scale 2^16).

Paper phases on a RAW inner node (not EH): (1) allocate n slots, (2) set
n indirections to n individual leaves, (3) optionally eagerly populate,
(4) 10M random accesses, (5) the same wave again.  The JAX mapping:

  traditional "set pointer"   -> int32 store into the directory array
  shortcut    "mmap per slot" -> page copy into the composed view
                                 (rewiring.compose)
  eager page-table population -> block_until_ready on the view
  lazy population             -> async dispatch; the first access wave
                                 pays materialization

Reproduction targets: the shortcut's set-indirection cost is orders of
magnitude above a pointer store (paper: 447.5 vs 2.1 us — mmap syscall
overhead; here: page-copy vs int-store bytes), eager population makes
the first wave much cheaper (paper: 3x), and steady-state access is
cheaper through the shortcut.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, sync, timeit
from repro.core import rewiring


def run(scale: float = 1.0 / 64):
    slots_log2 = max(12, int(np.log2(2 ** 22 * scale)))
    n_slots = 1 << slots_log2
    n_access = max(10_000, int(10_000_000 * scale))
    page = 512                      # 4KB page of u32 entries, 1:1 fan-in
    rng = np.random.default_rng(0)
    rows = []

    # (1) allocate: leaves live in the page pool; the inner node is a
    # directory of n_slots indirections (to n_slots individual leaves)
    pool = jnp.asarray(rng.integers(0, 2**31, (n_slots, page), np.int64)
                       .astype(np.uint32))
    perm = jnp.asarray(rng.permutation(n_slots).astype(np.int32))
    probe = jnp.asarray(rng.integers(0, n_slots, n_access)
                        .astype(np.int32))
    sync(pool), sync(perm), sync(probe)

    # (2) set indirections
    def set_traditional():
        return jnp.zeros((n_slots,), jnp.int32).at[
            jnp.arange(n_slots)].set(perm)

    directory = sync(set_traditional())
    t_trad_set = timeit(set_traditional) / n_slots * 1e6
    t_short_set = timeit(rewiring.compose, pool, directory) \
        / n_slots * 1e6

    def trad_access(d):
        leaf = d[probe]                      # explicit indirection
        return pool[leaf, probe % page].sum()  # leaf access

    def short_access(v):
        return v[probe, probe % page].sum()  # single indirection

    # lazy: compose dispatched, first wave pays materialization
    t0 = time.perf_counter()
    view = rewiring.compose(pool, directory)  # async dispatch
    sync(short_access(view))
    t_first_lazy = (time.perf_counter() - t0) / n_access * 1e6
    t_second_lazy = timeit(short_access, view) / n_access * 1e6

    # eager: populate first
    view = rewiring.compose(pool, directory)
    t0 = time.perf_counter()
    sync(view)
    t_populate = (time.perf_counter() - t0) / n_slots * 1e6
    t_first_eager = timeit(short_access, view, iters=1) / n_access * 1e6
    t_second_eager = timeit(short_access, view) / n_access * 1e6

    t_first_trad = timeit(trad_access, directory, iters=1) \
        / n_access * 1e6
    t_second_trad = timeit(trad_access, directory) / n_access * 1e6

    b = "table1"
    rows += [
        Row(b, "slots", n_slots, "count"),
        Row(b, "set_indirection_traditional", t_trad_set, "us/slot"),
        Row(b, "set_indirection_shortcut", t_short_set, "us/slot"),
        Row(b, "set_ratio", t_short_set / max(t_trad_set, 1e-9), "x",
            "paper: ~213x (447.5/2.1); here page-copy vs int-store"),
        Row(b, "populate_eager", t_populate, "us/slot"),
        Row(b, "access1_traditional", t_first_trad, "us/access"),
        Row(b, "access1_shortcut_lazy", t_first_lazy, "us/access"),
        Row(b, "access1_shortcut_eager", t_first_eager, "us/access"),
        Row(b, "access2_traditional", t_second_trad, "us/access"),
        Row(b, "access2_shortcut_lazy", t_second_lazy, "us/access"),
        Row(b, "access2_shortcut_eager", t_second_eager, "us/access"),
        Row(b, "first_access_eager_speedup",
            t_first_lazy / max(t_first_eager, 1e-9), "x",
            "paper: ~3x (here lazy pays the whole compose)"),
        Row(b, "steady_access_speedup",
            t_second_trad / max(t_second_eager, 1e-9), "x",
            "traditional/shortcut steady state"),
    ]
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
