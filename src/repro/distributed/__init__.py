from repro.distributed.sharding import (  # noqa: F401
    batch_spec, logical_spec, param_specs, shardings_for, ShardingRules)
