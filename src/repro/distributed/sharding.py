"""Divisibility-aware logical-axis sharding rules.

Every array in the system carries *logical* dimension names; this module maps
them onto physical mesh axes.  The mapping is **divisibility-aware**: each
logical name lists candidate mesh axes (or axis tuples) in priority order and
the first candidate whose size divides the dimension — and whose axes are not
already consumed by another dimension of the same array — wins.  Dims with no
viable candidate are replicated instead of failing to compile, which is what
lets every (arch x shape x mesh) cell lower even when e.g. ``kv_heads=8``
meets a 16-way model axis or ``num_experts=60`` meets a 16-way data axis.

Default logical -> physical intent (production mesh ``(pod, data, model)``):

  batch       -> (pod, data)      pure DP (pod x data combined)
  vocab/ff/heads/expert -> model  tensor parallelism (Megatron-style)
  embed       -> data             FSDP: weights sharded over the DP axis and
                                  all-gathered on use (ZeRO-3 via GSPMD)
  ctx         -> model            decode-time context/sequence parallelism
                                  (used when kv_heads cannot use `model`)

``param_specs`` walks a parameter pytree and assigns logical names from the
key path, so sharding stays centralized here rather than scattered through
model code.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, tuple]


@dataclass(frozen=True)
class ShardingRules:
    """Logical-name -> candidate physical axes (priority ordered)."""

    rules: dict = field(default_factory=dict)

    def candidates(self, name: Optional[str]) -> Sequence[Axis]:
        if name is None:
            return ()
        return self.rules.get(name, ())


def default_rules(mesh: Mesh) -> ShardingRules:
    """The production ruleset; adapts to whether a 'pod' axis exists."""
    dp: tuple = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return ShardingRules(rules={
        # data dims
        "batch": (dp, ("data",)),
        "seq": (),                       # train seq stays unsharded
        "ctx": (("model",), ("data",)),  # decode context (SP fallback)
        # weight dims
        "vocab": (("model",),),
        "embed": (("data",),),           # FSDP axis
        "heads": (("model",),),
        "kv_heads": (("model",),),
        "ff": (("model",),),
        "expert": (("model",), ("data",)),
        "ssm_inner": (("model",),),
        "ssm_heads": (("model",),),
        # serving dims
        "kv_seqs": (dp, ("data",)),      # sequences in the KV pool
        "blocks": (dp, ("data",)),       # physical KV blocks
        "head_dim": (("model",),),       # last-resort pool sharding
        # EH index dims (core/sharded_eh): a sharded index stacks its
        # per-shard structures on a leading `eh_shard` dim — one shard
        # per data slice keeps each shard's lookup local; the directory
        # and bucket-pool dims split over the model axis when a single
        # shard outgrows one device (the VMEM-regime escape hatch).
        # Bucket rows (`eh_slots`) stay contiguous: the probe is a
        # vectorized scan of one row and must never cross devices.
        "eh_shard": (dp, ("data",)),
        "eh_dir": (("model",), ("data",)),
        "eh_buckets": (("model",), ("data",)),
        "eh_slots": (),
        # sharded KV views (kvcache/shortcut_cache, num_shards=N): the
        # per-shard (L, seqs_per_shard, S_cap, KV, hd) pairs stack on a
        # leading `kv_shard` dim; like `eh_shard`, one shard per data
        # slice keeps each shard's replay and row-gather local.
        "kv_shard": (dp, ("data",)),
        # generic replicated
        "layer": (),
    })


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _axis_names(axis: Axis) -> tuple:
    return axis if isinstance(axis, tuple) else (axis,)


#: dims with higher priority claim physical axes first (lower = earlier).
#: ``ctx`` is the decode sequence-parallel *fallback* — it must not steal the
#: model axis from a divisible kv_heads/heads dim.
_NAME_PRIORITY = {"ctx": 5, "head_dim": 9}


def logical_spec(shape: Sequence[int], names: Sequence[Optional[str]],
                 mesh: Mesh, rules: Optional[ShardingRules] = None) -> P:
    """Resolve logical dim names to a PartitionSpec for ``mesh``.

    Greedy in name-priority order (TP dims before SP fallbacks); each
    physical axis is consumed at most once per array; a dim whose candidates
    all fail divisibility is replicated.
    """
    rules = rules or default_rules(mesh)
    assert len(shape) == len(names), (shape, names)
    used: set = set()
    entries: list = [None] * len(shape)
    order = sorted(range(len(shape)),
                   key=lambda i: (_NAME_PRIORITY.get(names[i], 0), i))
    for i in order:
        dim, name = shape[i], names[i]
        for cand in rules.candidates(name):
            ax = _axis_names(cand)
            if any(a not in mesh.axis_names for a in ax):
                continue
            if any(a in used for a in ax):
                continue
            if dim == 0 or dim % _axis_size(mesh, cand) != 0:
                continue
            entries[i] = cand if isinstance(cand, tuple) and len(cand) > 1 \
                else ax[0]
            used.update(ax)
            break
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def shardings_for(tree, names_tree, mesh: Mesh,
                  rules: Optional[ShardingRules] = None):
    """Map a pytree of arrays/ShapeDtypeStructs + parallel names pytree to
    NamedShardings."""
    return jax.tree.map(
        lambda x, names: NamedSharding(
            mesh, logical_spec(x.shape, names, mesh, rules)),
        tree, names_tree, is_leaf=lambda x: isinstance(x, (list, tuple)))


# ---------------------------------------------------------------------------
# Path-based parameter naming.
# ---------------------------------------------------------------------------

# (path regex, logical names for the *trailing* dims; a leading stacked layer
#  dim is auto-prefixed with "layer"). First match wins.
_PARAM_NAME_RULES: list[tuple[str, tuple]] = [
    (r"embed$", ("vocab", "embed")),
    (r"lm_head$", ("embed", "vocab")),
    (r"attn/wq$", ("embed", "heads")),
    (r"attn/wk$", ("embed", "kv_heads")),
    (r"attn/wv$", ("embed", "kv_heads")),
    (r"attn/wo$", ("heads", "embed")),
    (r"attn/(q_norm|k_norm)$", (None,)),
    (r"mlp/wi$", ("embed", "ff")),
    (r"mlp/wo$", ("ff", "embed")),
    (r"moe/router$", ("embed", "expert")),
    (r"moe/wi$", ("expert", "embed", "ff")),
    (r"moe/wo$", ("expert", "ff", "embed")),
    (r"moe/shared/wi$", ("embed", "ff")),
    (r"moe/shared/wo$", ("ff", "embed")),
    (r"ssm/in_proj$", ("embed", "ssm_inner")),
    (r"ssm/out_proj$", ("ssm_inner", "embed")),
    (r"ssm/conv_w$", (None, "ssm_inner")),
    (r"ssm/conv_b$", ("ssm_inner",)),
    (r"ssm/(A_log|D|dt_bias)$", ("ssm_heads",)),
    (r"ssm/norm$", ("ssm_inner",)),
    (r"(ln1|ln2|final_norm|norm)$", (None,)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_names(params) -> "jax.tree_util.PyTreeDef":
    """Pytree of logical-name tuples parallel to ``params``."""
    def name_leaf(path, leaf):
        s = _path_str(path)
        stacked = s.startswith("layers/")
        for pat, names in _PARAM_NAME_RULES:
            if re.search(pat, s):
                full = (("layer",) if stacked else ()) + names
                if len(full) == leaf.ndim:
                    return list(full)
                if len(full) < leaf.ndim:  # e.g. scalars broadcast
                    return list(full) + [None] * (leaf.ndim - len(full))
                return list(full[:leaf.ndim])
        return [None] * leaf.ndim

    return jax.tree_util.tree_map_with_path(name_leaf, params)


def param_specs(params, mesh: Mesh,
                rules: Optional[ShardingRules] = None):
    """NamedSharding pytree for a parameter pytree (or ShapeDtypeStructs)."""
    names = param_names(params)
    return jax.tree.map(
        lambda x, n: NamedSharding(mesh, logical_spec(x.shape, n, mesh,
                                                      rules)),
        params, names, is_leaf=lambda x: hasattr(x, "shape"))


def batch_spec(batch, mesh: Mesh, rules: Optional[ShardingRules] = None):
    """Shard every batch leaf on its leading (batch) dim only."""
    def spec(x):
        names = ["batch"] + [None] * (x.ndim - 1)
        return NamedSharding(mesh, logical_spec(x.shape, names, mesh, rules))
    return jax.tree.map(spec, batch)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


#: Logical names of the stacked sharded-EH lookup operands
#: (``core/sharded_eh.ShardedShortcutEH.lookup_batched``) — resolved by
#: the same divisibility-aware rules as every other array in the system.
EH_LOOKUP_NAMES = {
    "keys": ("eh_shard", None),                      # (N, K)
    "directories": ("eh_shard", "eh_dir"),           # (N, D)
    "bucket_keys": ("eh_shard", "eh_buckets", "eh_slots"),   # (N, C, S)
    "bucket_vals": ("eh_shard", "eh_buckets", "eh_slots"),
    "view_keys": ("eh_shard", "eh_dir", "eh_slots"),         # (N, V, S)
    "view_vals": ("eh_shard", "eh_dir", "eh_slots"),
    "global_depths": (None,),                        # (N,) tiny: replicate
}


def sharded_eh_specs(operands: dict, mesh: Mesh,
                     rules: Optional[ShardingRules] = None) -> dict:
    """NamedShardings for a dict of sharded-EH lookup operands, keyed by
    the :data:`EH_LOOKUP_NAMES` operand names.  Indivisible dims (e.g.
    2 shards on a 16-way data axis) replicate instead of failing, per
    the module's contract."""
    return {k: NamedSharding(
                mesh, logical_spec(v.shape, EH_LOOKUP_NAMES[k], mesh, rules))
            for k, v in operands.items()}


#: Logical names of the stacked per-shard KV view arrays
#: (``kvcache/shortcut_cache.ShortcutKVManager`` with ``num_shards=N``):
#: each shard's (L, seqs_per_shard, S_cap, KV, hd) pair stacked on a
#: leading ``kv_shard`` dim, e.g. ``jnp.stack([k for k, _ in views])``.
KV_VIEW_NAMES = {
    "view_k": ("kv_shard", "layer", "kv_seqs", "ctx", "kv_heads",
               "head_dim"),
    "view_v": ("kv_shard", "layer", "kv_seqs", "ctx", "kv_heads",
               "head_dim"),
}


def sharded_kv_view_specs(operands: dict, mesh: Mesh,
                          rules: Optional[ShardingRules] = None) -> dict:
    """NamedShardings for stacked per-shard KV view arrays, keyed by the
    :data:`KV_VIEW_NAMES` operand names; same divisibility-aware
    replicate-don't-fail contract as :func:`sharded_eh_specs`."""
    return {k: NamedSharding(
                mesh, logical_spec(v.shape, KV_VIEW_NAMES[k], mesh, rules))
            for k, v in operands.items()}


# ---------------------------------------------------------------------------
# In-model sharding hints (active-mesh context).
#
# Model code cannot know the mesh, but a few intermediates (chunked-loss
# logits, MoE dispatch buffers) MUST be pinned or GSPMD reshards them to
# something catastrophic (e.g. gathering full-vocab logits per device).  The
# launcher activates a mesh; ``constrain`` is a no-op outside that context,
# so tests and single-device runs are untouched.
# ---------------------------------------------------------------------------

import contextlib
import threading

_ACTIVE = threading.local()


@contextlib.contextmanager
def activate_mesh(mesh: Mesh, rules: Optional[ShardingRules] = None):
    prev = getattr(_ACTIVE, "mesh", None), getattr(_ACTIVE, "rules", None)
    _ACTIVE.mesh, _ACTIVE.rules = mesh, rules
    try:
        yield
    finally:
        _ACTIVE.mesh, _ACTIVE.rules = prev


def constrain(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """Pin ``x`` to the logical spec under the active mesh (no-op if none)."""
    mesh = getattr(_ACTIVE, "mesh", None)
    if mesh is None:
        return x
    rules = getattr(_ACTIVE, "rules", None)
    spec = logical_spec(x.shape, names, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
