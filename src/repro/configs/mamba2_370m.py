"""Mamba2-370m: attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="mamba2_370m", family="ssm",
    num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    tie_embeddings=True,
))
