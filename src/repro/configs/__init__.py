from repro.configs.base import ArchConfig, get, list_archs, register, ASSIGNED  # noqa: F401
