"""Snowflake Arctic base: dense-MoE hybrid — a dense FFN residual runs in
parallel with a 128-expert top-2 MoE every layer. [hf:Snowflake/snowflake-arctic-base]"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="arctic_480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000, head_dim=128,
    num_experts=128, top_k=2, moe_d_ff=4864, dense_residual=True,
    # 56 q-heads don't divide the 16-way model axis: pad groups 7->8
    # (H 56->64, mathematically inert; EXPERIMENTS.md §Perf iter 6)
    pad_q_groups=8,
))
