"""Cohere Command R+: GQA, no-bias dense transformer.
[hf:CohereForAI/c4ai-command-r-v01]"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="command_r_plus_104b", family="dense",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=33792, vocab_size=256000, head_dim=128,
    rope_theta=75000000.0, tie_embeddings=True,
))
