"""InternLM2-1.8B: GQA dense. [arXiv:2403.17297]"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="internlm2_1_8b", family="dense",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92544, head_dim=128, rope_theta=1000000.0,
))
