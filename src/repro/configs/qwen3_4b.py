"""Qwen3-4B: GQA + qk_norm dense. [hf:Qwen/Qwen3-8B family]"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="qwen3_4b", family="dense",
    num_layers=36, d_model=2560, num_heads=32, num_kv_heads=8,
    d_ff=9728, vocab_size=151936, head_dim=128,
    qk_norm=True, rope_theta=1000000.0, tie_embeddings=True,
))
