"""Hymba-1.5B: hybrid heads — attention and Mamba(SSM) heads run in
*parallel* within each layer; sliding-window attention everywhere except
three global layers (first / middle / last). [arXiv:2411.13676]"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="hymba_1_5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64,
    sliding_window=1024, global_layers=(0, 15, 31),
    tie_embeddings=True,
))
