"""Architecture configuration system.

``ArchConfig`` is a frozen dataclass describing one LM backbone; every
assigned architecture registers an instance via :func:`register` in its own
``configs/<id>.py``.  ``reduced()`` derives the CPU smoke-test variant
(same family/topology, tiny dims).  ``get(name)`` / ``list_archs()`` are the
public registry API used by the launcher (``--arch <id>``).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Optional

_REGISTRY: dict[str, "ArchConfig"] = {}

#: assigned architecture ids (public pool), imported lazily by get()
ASSIGNED = (
    "arctic_480b", "qwen2_moe_a2_7b", "mamba2_370m", "command_r_plus_104b",
    "internlm2_1_8b", "qwen3_4b", "gemma2_27b", "musicgen_medium",
    "paligemma_3b", "hymba_1_5b",
)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                   # 0 for attention-free
    num_kv_heads: int
    d_ff: int                        # dense MLP hidden (0 = no dense MLP)
    vocab_size: int
    head_dim: int = 0                # 0 => d_model // num_heads

    # attention details
    qk_norm: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    sliding_window: Optional[int] = None
    local_global_period: int = 0     # gemma2: every p-th layer is global
    global_layers: tuple = ()        # hymba: explicit global layer ids
    rope_theta: float = 10000.0
    attn_bias: bool = False
    # mesh-divisibility head padding (activation-level, mathematically
    # inert: dead q-heads are zero -> their outputs are sliced off before
    # wo; dead kv-groups receive only dead q-heads).  0 = no padding.
    pad_kv_heads: int = 0        # pad num_kv_heads to this
    pad_q_groups: int = 0        # pad per-kv q-group size to this

    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    shared_d_ff: int = 0
    dense_residual: bool = False     # arctic: dense FFN parallel to MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # modality frontend (audio/vlm backbones get precomputed embeddings)
    input_mode: str = "tokens"       # tokens | embeddings | prefix_embeddings
    prefix_len: int = 0              # paligemma: image patch tokens

    tie_embeddings: bool = False
    act: str = "silu"
    norm_eps: float = 1e-6
    loss_chunk: int = 2048           # chunked cross-entropy (memory control)
    attn_chunk_q: int = 2048         # blockwise-attention tile sizes
    attn_chunk_kv: int = 1024

    # -- derived -------------------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def has_attention(self) -> bool:
        return self.num_heads > 0

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k: no *unwindowed* attention layer...
        pure SSM, or hybrid whose attention is sliding-window except a
        bounded set of global layers (hymba) — decode stays O(window + g)."""
        if not self.has_attention:
            return True
        return self.has_ssm and self.sliding_window is not None

    def num_params(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs)."""
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        n = self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            n += d * self.vocab_size                 # lm head
        per = 0
        if self.has_attention:
            per += d * (self.num_heads * hd) * 2     # wq, wo
            per += d * (self.num_kv_heads * hd) * 2  # wk, wv
            if self.qk_norm:
                per += 2 * hd
        if self.has_ssm:
            di, ns, nh = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            per += d * (2 * di + 2 * ns + nh)        # in_proj
            per += di * d                            # out_proj
            per += (di + 2 * ns) * self.ssm_conv     # conv
            per += 3 * nh + di                       # A, D, dt_bias, norm
        if self.d_ff:
            per += 3 * d * self.d_ff                 # SwiGLU
        if self.num_experts:
            per += d * self.num_experts              # router
            per += self.num_experts * 3 * d * self.moe_d_ff
            if self.shared_d_ff:
                per += 3 * d * self.shared_d_ff
        per += 2 * d                                 # ln1, ln2
        return n + per * L + d                       # + final norm

    def num_active_params(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.num_experts:
            return self.num_params()
        dense = dataclasses.replace(
            self, num_experts=0, top_k=0, moe_d_ff=0, shared_d_ff=0)
        active_moe = (self.top_k * 3 * self.d_model * self.moe_d_ff
                      + 3 * self.d_model * self.shared_d_ff
                      + self.d_model * self.num_experts) * self.num_layers
        return dense.num_params() + active_moe

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        def cap(v, m):
            return min(v, m) if v else v
        return dataclasses.replace(
            self,
            num_layers=min(self.num_layers, 2),
            d_model=cap(self.d_model, 64),
            num_heads=cap(self.num_heads, 4),
            num_kv_heads=cap(self.num_kv_heads, 2),
            head_dim=16 if self.num_heads else 0,
            d_ff=cap(self.d_ff, 128),
            vocab_size=cap(self.vocab_size, 256),
            num_experts=cap(self.num_experts, 8),
            top_k=cap(self.top_k, 2),
            moe_d_ff=cap(self.moe_d_ff, 64),
            num_shared_experts=cap(self.num_shared_experts, 1),
            shared_d_ff=cap(self.shared_d_ff, 64),
            # ample capacity: reduced-config tests compare decode vs full
            # forward, which must not differ by capacity dropping
            capacity_factor=8.0,
            pad_kv_heads=0, pad_q_groups=0,  # no padding at toy sizes
            ssm_state=cap(self.ssm_state, 16),
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=16 if self.ssm_state else 128,
            sliding_window=cap(self.sliding_window, 32),
            global_layers=tuple(g for g in self.global_layers if g < 2),
            prefix_len=cap(self.prefix_len, 8),
            loss_chunk=64,
            attn_chunk_q=32,
            attn_chunk_kv=32,
        )


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ArchConfig:
    key = name.replace("-", "_").replace(".", "_")
    if key not in _REGISTRY:
        importlib.import_module(f"repro.configs.{key}")
    return _REGISTRY[key]


def list_archs() -> list[str]:
    for key in ASSIGNED:
        if key not in _REGISTRY:
            importlib.import_module(f"repro.configs.{key}")
    return sorted(_REGISTRY)
