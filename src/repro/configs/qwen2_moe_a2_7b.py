"""Qwen1.5-MoE-A2.7B: 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="qwen2_moe_a2_7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=0, vocab_size=151936, head_dim=128,
    num_experts=60, top_k=4, moe_d_ff=1408,
    num_shared_experts=4, shared_d_ff=5632,
    rope_theta=1000000.0, attn_bias=True,
))
