"""PaliGemma-3B: SigLIP vision frontend (stubbed — input_specs() provides
precomputed patch embeddings as a 256-token prefix) + Gemma-2B decoder
(MQA, head_dim 256). [arXiv:2407.07726]"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="paligemma_3b", family="vlm",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
    d_ff=16384, vocab_size=257216, head_dim=256,
    input_mode="prefix_embeddings", prefix_len=256,
    act="gelu", tie_embeddings=True,
    pad_q_groups=16,  # MQA: 8 q-heads -> 16 for the model axis
))
