"""MusicGen-medium: decoder-only transformer over EnCodec tokens; the
EnCodec frontend is a stub — input_specs() provides precomputed frame
embeddings. [arXiv:2306.05284]"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="musicgen_medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    input_mode="embeddings", act="gelu",
    pad_kv_heads=32,  # 24 MHA heads -> 32 for the 16-way model axis
))
