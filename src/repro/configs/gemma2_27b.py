"""Gemma2-27B: local(4096-window)/global alternating attention, attn+final
logit softcaps, GQA. [arXiv:2408.00118]"""
from repro.configs.base import ArchConfig, register

register(ArchConfig(
    name="gemma2_27b", family="dense",
    num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16,
    d_ff=36864, vocab_size=256000, head_dim=128,
    sliding_window=4096, local_global_period=2,
    attn_softcap=50.0, final_softcap=30.0, act="gelu",
    tie_embeddings=True,
))
