"""Memory-rewiring abstraction: the TPU/JAX analogue of RUMA-style rewiring.

The paper builds shortcuts out of three OS facilities:

  * a *physical page pool*  -- a ``memfd_create`` main-memory file that grows/
    shrinks with ``ftruncate`` and keeps a queue of free page offsets,
  * a *virtual memory area* -- ``mmap(MAP_ANON)`` reservations, and
  * *rewiring*              -- per-page ``mmap(MAP_SHARED|MAP_FIXED)`` calls
    that point virtual pages straight at pool pages.

On TPU none of these exist, so we adapt the *insight* (see DESIGN.md section 2):

  * :class:`PagePool`  -- a preallocated ``(capacity, page_slots)`` HBM array
    plus a ring-buffer free list.  ``alloc``/``free`` mirror the paper's
    offset queue; the high-water mark mirrors the ``ftruncate`` size.
  * a *composed view*  -- ``view = pool.pages[directory]``: the one-time
    materialization that replaces the page-table remap.  After composition the
    hot path performs **address arithmetic + one contiguous read** instead of
    two dependent gathers, which is exactly the indirection count the paper's
    shortcut achieves (one hardware-resolved translation).
  * :func:`remap_slots` -- the per-slot ``mmap`` replay used by *update*
    maintenance requests.

Everything here is functional and jittable; host-side orchestration lives in
``shortcut_eh.py``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class PagePool(NamedTuple):
    """A self-managed pool of physical pages (the ``memfd`` analogue).

    ``pages``     -- (capacity, page_slots) backing storage.
    ``free_ring`` -- ring buffer of free page offsets (the paper's queue of
                     unused offsets).
    ``free_head`` -- index of the next offset to pop.
    ``free_count``-- number of offsets currently in the ring.
    ``size``      -- high-water mark: pages [0, size) have been handed out at
                     least once (the ``ftruncate`` file size).
    """

    pages: jax.Array       # (capacity, page_slots) payload
    free_ring: jax.Array   # (capacity,) int32 ring buffer of free offsets
    free_head: jax.Array   # () int32
    free_count: jax.Array  # () int32
    size: jax.Array        # () int32 high-water mark

    @property
    def capacity(self) -> int:
        return self.pages.shape[0]

    @property
    def page_shape(self) -> tuple[int, ...]:
        return self.pages.shape[1:]

    @property
    def page_slots(self) -> int:
        return self.pages.shape[1]


def pool_create(capacity: int, page_slots, dtype=jnp.int32,
                fill=0) -> PagePool:
    """Create an empty pool. ``fill`` initializes pages (hard-fault avoidance
    in the paper; here it fixes the sentinel for empty slots).

    ``page_slots`` may be an int (flat pages) or a tuple (structured pages,
    e.g. ``(block_size, kv_heads, head_dim)`` for KV-cache pages).
    """
    shape = (page_slots,) if isinstance(page_slots, int) else tuple(page_slots)
    return PagePool(
        pages=jnp.full((capacity,) + shape, fill, dtype=dtype),
        free_ring=jnp.zeros((capacity,), jnp.int32),
        free_head=jnp.zeros((), jnp.int32),
        free_count=jnp.zeros((), jnp.int32),
        size=jnp.zeros((), jnp.int32),
    )


def pool_alloc(pool: PagePool) -> tuple[PagePool, jax.Array]:
    """Pop a free offset if available, else extend the high-water mark.

    Returns ``(pool, offset)``; ``offset == -1`` signals exhaustion (the
    caller decides whether that is a hard error).
    """
    def from_ring(p: PagePool):
        off = p.free_ring[p.free_head % p.capacity]
        return p._replace(
            free_head=(p.free_head + 1) % p.capacity,
            free_count=p.free_count - 1,
        ), off

    def from_hwm(p: PagePool):
        off = jnp.where(p.size < p.capacity, p.size, -1)
        return p._replace(size=jnp.minimum(p.size + 1, p.capacity)), off

    return jax.lax.cond(pool.free_count > 0, from_ring, from_hwm, pool)


def pool_free(pool: PagePool, offset: jax.Array,
              reset_fill=None) -> PagePool:
    """Return ``offset`` to the free ring (the paper shrinks the file when the
    freed page is at the end; with fixed capacity we always ring-buffer it).
    ``reset_fill`` optionally re-initializes the page payload."""
    tail = (pool.free_head + pool.free_count) % pool.capacity
    pool = pool._replace(
        free_ring=pool.free_ring.at[tail].set(offset.astype(jnp.int32)),
        free_count=pool.free_count + 1,
    )
    if reset_fill is not None:
        pool = pool._replace(
            pages=pool.pages.at[offset].set(
                jnp.full(pool.page_shape, reset_fill, pool.pages.dtype)))
    return pool


def pool_read(pool: PagePool, offset: jax.Array) -> jax.Array:
    return pool.pages[offset]


def pool_write(pool: PagePool, offset: jax.Array, page: jax.Array) -> PagePool:
    return pool._replace(pages=pool.pages.at[offset].set(page))


def pool_used_pages(pool: PagePool) -> jax.Array:
    """Number of live pages (handed out and not freed)."""
    return pool.size - pool.free_count


# ---------------------------------------------------------------------------
# Shortcut composition: the page-table remap analogue.
# ---------------------------------------------------------------------------

def compose(pool_pages: jax.Array, directory: jax.Array) -> jax.Array:
    """Materialize the composed view ``view[i] = pool_pages[directory[i]]``.

    This is the *create request* replay: one gather that plays the role of the
    ``mmap`` loop in the paper's step (2).  It is deliberately expensive
    (O(slots x page_slots) bytes moved, vs O(slots x 8B) for pointer stores)
    -- the two-orders-of-magnitude creation cost of Table 1 transfers
    directly, and is likewise hidden asynchronously by the caller.
    """
    return jnp.take(pool_pages, directory, axis=0)


def remap_slots(view: jax.Array, pool_pages: jax.Array,
                slots: jax.Array, offsets: jax.Array) -> jax.Array:
    """Replay *update requests*: ``view[slots[j]] = pool_pages[offsets[j]]``.

    The paper's per-slot ``mmap(MAP_SHARED|MAP_FIXED)``.  ``slots`` and
    ``offsets`` are parallel 1-D arrays; duplicate slots resolve to the last
    write (matching sequential mmap calls).
    """
    return view.at[slots].set(jnp.take(pool_pages, offsets, axis=0))


@functools.partial(jax.jit, static_argnames=("length",))
def remap_range(view: jax.Array, pool_pages: jax.Array,
                start: jax.Array, length: int,
                offset: jax.Array) -> jax.Array:
    """Remap ``length`` *contiguous* view slots to the same pool page.

    The paper coalesces neighboring remaps into a single ``mmap`` call; a
    contiguous directory range pointing at one bucket is exactly the fan-in>1
    situation in extendible hashing.  ``length`` is static (powers of two in
    EH), so this lowers to one dynamic_update_slice.
    """
    page = pool_pages[offset]
    block = jnp.broadcast_to(page, (length,) + page.shape)
    return jax.lax.dynamic_update_slice(
        view, block, (start,) + (0,) * page.ndim)
