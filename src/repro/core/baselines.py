"""The paper's §4.2 baselines, jittable in JAX.

  * :class:`HTState`  -- Hash Table (HT): one open-addressing / linear-probing
    table, doubled + fully rehashed when the load factor crosses a threshold.
  * :class:`HTIState` -- Hash Table Incremental (HTI, Redis-style [1]): as HT,
    but the rehash moves only ``migrate_batch`` entries per access while both
    tables co-exist; lookups inspect the fuller table first.
  * :class:`CHState`  -- Chained Hashing (CH): fixed-size table of chain heads
    over fixed 128 B buckets; overflow appends a bucket to the chain.

All tables use the same multiplicative hash as the EH implementation
(``core/hashing.py``, the single home of the constants and the masked
linear-probe primitives), matching the paper's comparability setup.
Static maximum capacities + dynamic active sizes keep everything jittable.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.core.hashing import EMPTY_KEY, MISS, dir_slot, hash_dir

_PROBE_WINDOW = 32  # static linear-probe window; ample for load <= 0.35

# Open-addressing home slot: top ``size_log2`` bits (MSB, as in EH).
_slot_of = dir_slot


def _probe_insert(keys, vals, key, value, size_log2):
    """Linear-probe insert into the active prefix [0, 2^size_log2).

    Returns (keys, vals, inserted_new, ok)."""
    pos = hashing.window_positions(hash_dir(key), size_log2, _PROBE_WINDOW)
    ok, j = hashing.probe_slot(keys[pos], key)
    idx = pos[j]
    was_empty = keys[idx] == EMPTY_KEY
    keys = keys.at[idx].set(jnp.where(ok, key.astype(jnp.uint32), keys[idx]))
    vals = vals.at[idx].set(jnp.where(ok, value.astype(jnp.uint32), vals[idx]))
    return keys, vals, (ok & was_empty).astype(jnp.int32), ok


def _probe_find(keys, vals, key, size_log2):
    pos = hashing.window_positions(hash_dir(key), size_log2, _PROBE_WINDOW)
    found, j = hashing.probe_hit(keys[pos], key)
    return jnp.where(found, vals[pos[j]], MISS)


# ---------------------------------------------------------------------------
# HT: full-stop rehash.
# ---------------------------------------------------------------------------

class HTState(NamedTuple):
    keys: jax.Array       # (max_cap,) uint32
    vals: jax.Array       # (max_cap,) uint32
    size_log2: jax.Array  # () int32
    count: jax.Array      # () int32
    dropped: jax.Array    # () int32

    @property
    def max_size_log2(self) -> int:
        return int(self.keys.shape[0]).bit_length() - 1


def ht_create(max_size_log2: int, initial_size_log2: int = 9) -> HTState:
    cap = 1 << max_size_log2
    return HTState(
        keys=jnp.full((cap,), EMPTY_KEY, jnp.uint32),
        vals=jnp.zeros((cap,), jnp.uint32),
        size_log2=jnp.int32(initial_size_log2),
        count=jnp.zeros((), jnp.int32),
        dropped=jnp.zeros((), jnp.int32),
    )


def _ht_rehash_doubled(st: HTState) -> HTState:
    """Allocate 2x and move every entry (the staircase step of Fig. 7a)."""
    new_log2 = jnp.minimum(st.size_log2 + 1, st.max_size_log2)

    def move(i, carry):
        keys, vals = carry
        key = st.keys[i]
        val = st.vals[i]

        def do(kv):
            k, v = kv
            k, v, _, _ = _probe_insert(k, v, key, val, new_log2)
            return k, v

        return jax.lax.cond(key != EMPTY_KEY, do, lambda kv: kv, (keys, vals))

    empty = jnp.full_like(st.keys, EMPTY_KEY), jnp.zeros_like(st.vals)
    keys, vals = jax.lax.fori_loop(0, st.keys.shape[0], move, empty)
    return st._replace(keys=keys, vals=vals, size_log2=new_log2)


def ht_insert(st: HTState, key, value,
              load_threshold: float = 0.35) -> HTState:
    size = (jnp.int32(1) << st.size_log2).astype(jnp.float32)
    needs = (st.count.astype(jnp.float32) + 1.0) > load_threshold * size
    can = st.size_log2 < st.max_size_log2
    st = jax.lax.cond(needs & can, _ht_rehash_doubled, lambda s: s, st)
    keys, vals, inew, ok = _probe_insert(
        st.keys, st.vals, key, value, st.size_log2)
    return st._replace(keys=keys, vals=vals, count=st.count + inew,
                       dropped=st.dropped + (1 - ok.astype(jnp.int32)))


@jax.jit
def ht_insert_many(st: HTState, keys, values) -> HTState:
    def body(s, kv):
        return ht_insert(s, kv[0], kv[1]), None
    st, _ = jax.lax.scan(body, st, jnp.stack(
        [keys.astype(jnp.uint32), values.astype(jnp.uint32)], axis=1))
    return st


@jax.jit
def ht_lookup_many(st: HTState, keys) -> jax.Array:
    return jax.vmap(
        lambda k: _probe_find(st.keys, st.vals, k, st.size_log2)
    )(keys.astype(jnp.uint32))


# ---------------------------------------------------------------------------
# HTI: Redis-style incremental rehash.
# ---------------------------------------------------------------------------

class HTIState(NamedTuple):
    old_keys: jax.Array
    old_vals: jax.Array
    new_keys: jax.Array
    new_vals: jax.Array
    old_log2: jax.Array
    new_log2: jax.Array
    old_count: jax.Array
    new_count: jax.Array
    migrate_ptr: jax.Array  # () int32; == 2^old_log2 when drained
    migrating: jax.Array    # () bool_
    dropped: jax.Array

    @property
    def max_size_log2(self) -> int:
        return int(self.new_keys.shape[0]).bit_length() - 1


def hti_create(max_size_log2: int, initial_size_log2: int = 9) -> HTIState:
    cap = 1 << max_size_log2
    z = lambda: jnp.full((cap,), EMPTY_KEY, jnp.uint32)
    v = lambda: jnp.zeros((cap,), jnp.uint32)
    return HTIState(
        old_keys=z(), old_vals=v(), new_keys=z(), new_vals=v(),
        old_log2=jnp.int32(initial_size_log2),
        new_log2=jnp.int32(initial_size_log2),
        old_count=jnp.zeros((), jnp.int32),
        new_count=jnp.zeros((), jnp.int32),
        migrate_ptr=jnp.int32(1 << initial_size_log2),
        migrating=jnp.zeros((), jnp.bool_),
        dropped=jnp.zeros((), jnp.int32),
    )


def _hti_migrate(st: HTIState, batch: int) -> HTIState:
    """Move up to ``batch`` live entries old -> new (one Redis rehash step)."""
    def step(_, s: HTIState) -> HTIState:
        def move(s: HTIState) -> HTIState:
            i = s.migrate_ptr
            key, val = s.old_keys[i], s.old_vals[i]

            def do(s: HTIState) -> HTIState:
                k, v, inew, _ = _probe_insert(
                    s.new_keys, s.new_vals, key, val, s.new_log2)
                return s._replace(
                    new_keys=k, new_vals=v, new_count=s.new_count + inew,
                    old_keys=s.old_keys.at[i].set(EMPTY_KEY),
                    old_count=s.old_count - 1)

            s = jax.lax.cond(key != EMPTY_KEY, do, lambda x: x, s)
            return s._replace(migrate_ptr=i + 1)

        active = s.migrating & (s.migrate_ptr < (jnp.int32(1) << s.old_log2))
        return jax.lax.cond(active, move, lambda x: x, s)

    st = jax.lax.fori_loop(0, batch, step, st)
    drained = st.migrate_ptr >= (jnp.int32(1) << st.old_log2)
    return st._replace(migrating=st.migrating & ~drained)


def hti_insert(st: HTIState, key, value, load_threshold: float = 0.35,
               migrate_batch: int = 64) -> HTIState:
    st = _hti_migrate(st, migrate_batch)
    size = (jnp.int32(1) << st.new_log2).astype(jnp.float32)
    needs = ((st.new_count + st.old_count).astype(jnp.float32) + 1.0) \
        > load_threshold * size
    can = (~st.migrating) & (st.new_log2 < st.max_size_log2)

    def start_migration(s: HTIState) -> HTIState:
        return HTIState(
            old_keys=s.new_keys, old_vals=s.new_vals,
            new_keys=jnp.full_like(s.new_keys, EMPTY_KEY),
            new_vals=jnp.zeros_like(s.new_vals),
            old_log2=s.new_log2, new_log2=s.new_log2 + 1,
            old_count=s.new_count, new_count=jnp.zeros((), jnp.int32),
            migrate_ptr=jnp.zeros((), jnp.int32),
            migrating=jnp.ones((), jnp.bool_), dropped=s.dropped)

    st = jax.lax.cond(needs & can, start_migration, lambda s: s, st)
    keys, vals, inew, ok = _probe_insert(
        st.new_keys, st.new_vals, key, value, st.new_log2)
    return st._replace(new_keys=keys, new_vals=vals,
                       new_count=st.new_count + inew,
                       dropped=st.dropped + (1 - ok.astype(jnp.int32)))


@functools.partial(jax.jit, static_argnames=("migrate_batch",))
def hti_insert_many(st: HTIState, keys, values,
                    migrate_batch: int = 64) -> HTIState:
    def body(s, kv):
        return hti_insert(s, kv[0], kv[1],
                          migrate_batch=migrate_batch), None
    st, _ = jax.lax.scan(body, st, jnp.stack(
        [keys.astype(jnp.uint32), values.astype(jnp.uint32)], axis=1))
    return st


def hti_lookup(st: HTIState, key) -> jax.Array:
    """Check the fuller table first, fall back to the other (paper §4.2)."""
    from_new = _probe_find(st.new_keys, st.new_vals, key, st.new_log2)
    from_old = _probe_find(st.old_keys, st.old_vals, key, st.old_log2)
    new_first = st.new_count >= st.old_count
    first = jnp.where(new_first, from_new, from_old)
    second = jnp.where(new_first, from_old, from_new)
    return jnp.where(first != MISS, first, second)


@jax.jit
def hti_lookup_many(st: HTIState, keys) -> jax.Array:
    return jax.vmap(lambda k: hti_lookup(st, k))(keys.astype(jnp.uint32))


# ---------------------------------------------------------------------------
# CH: chained hashing over fixed 128 B buckets.
# ---------------------------------------------------------------------------

class CHState(NamedTuple):
    heads: jax.Array        # (table_size,) int32 chain head bucket id or -1
    bucket_keys: jax.Array  # (capacity, bucket_slots) uint32
    bucket_vals: jax.Array  # (capacity, bucket_slots) uint32
    next_bucket: jax.Array  # (capacity,) int32 link or -1
    counts: jax.Array       # (capacity,) int32
    num_buckets: jax.Array  # () int32
    dropped: jax.Array

    @property
    def table_log2(self) -> int:
        return int(self.heads.shape[0]).bit_length() - 1


def ch_create(table_log2: int, capacity: int,
              bucket_slots: int = 16) -> CHState:
    return CHState(
        heads=jnp.full((1 << table_log2,), -1, jnp.int32),
        bucket_keys=jnp.full((capacity, bucket_slots), EMPTY_KEY, jnp.uint32),
        bucket_vals=jnp.zeros((capacity, bucket_slots), jnp.uint32),
        next_bucket=jnp.full((capacity,), -1, jnp.int32),
        counts=jnp.zeros((capacity,), jnp.int32),
        num_buckets=jnp.zeros((), jnp.int32),
        dropped=jnp.zeros((), jnp.int32),
    )


def _ch_tail(st: CHState, head: jax.Array):
    """Walk to the chain's last bucket (or -1 for an empty chain)."""
    def cond(c):
        cur = c
        return (cur >= 0) & (st.next_bucket[cur] >= 0)
    return jax.lax.while_loop(cond, lambda c: st.next_bucket[c], head)


def ch_insert(st: CHState, key, value) -> CHState:
    slot = _slot_of(hash_dir(key), jnp.int32(st.table_log2))
    tail = _ch_tail(st, st.heads[slot])
    bucket_slots = st.bucket_keys.shape[1]
    tail_has_room = jnp.where(
        tail >= 0, st.counts[jnp.maximum(tail, 0)] < bucket_slots, False)
    can_alloc = st.num_buckets < st.bucket_keys.shape[0]

    def into_tail(s: CHState) -> CHState:
        i = s.counts[tail]  # append position (no deletes in the workload)
        return s._replace(
            bucket_keys=s.bucket_keys.at[tail, i].set(key.astype(jnp.uint32)),
            bucket_vals=s.bucket_vals.at[tail, i].set(
                value.astype(jnp.uint32)),
            counts=s.counts.at[tail].add(1))

    def into_new(s: CHState) -> CHState:
        b = s.num_buckets
        s = s._replace(
            bucket_keys=s.bucket_keys.at[b, 0].set(key.astype(jnp.uint32)),
            bucket_vals=s.bucket_vals.at[b, 0].set(value.astype(jnp.uint32)),
            counts=s.counts.at[b].set(1),
            num_buckets=s.num_buckets + 1)
        # link: empty chain -> head, else tail.next
        s = jax.lax.cond(
            tail < 0,
            lambda x: x._replace(heads=x.heads.at[slot].set(b)),
            lambda x: x._replace(next_bucket=x.next_bucket.at[tail].set(b)),
            s)
        return s

    def dropped(s: CHState) -> CHState:
        return s._replace(dropped=s.dropped + 1)

    return jax.lax.cond(
        tail_has_room, into_tail,
        lambda s: jax.lax.cond(can_alloc, into_new, dropped, s), st)


@jax.jit
def ch_insert_many(st: CHState, keys, values) -> CHState:
    def body(s, kv):
        return ch_insert(s, kv[0], kv[1]), None
    st, _ = jax.lax.scan(body, st, jnp.stack(
        [keys.astype(jnp.uint32), values.astype(jnp.uint32)], axis=1))
    return st


def ch_lookup(st: CHState, key) -> jax.Array:
    slot = _slot_of(hash_dir(key), jnp.int32(st.table_log2))

    def cond(c):
        cur, found = c
        return (cur >= 0) & (found == MISS)

    def body(c):
        cur, _ = c
        row = st.bucket_keys[cur]
        hit = row == key.astype(jnp.uint32)
        found = jnp.any(hit)
        val = jnp.where(found, st.bucket_vals[cur][jnp.argmax(hit)], MISS)
        return jnp.where(found, cur, st.next_bucket[cur]), val

    _, val = jax.lax.while_loop(cond, body, (st.heads[slot], MISS))
    return val


@jax.jit
def ch_lookup_many(st: CHState, keys) -> jax.Array:
    return jax.vmap(lambda k: ch_lookup(st, k))(keys.astype(jnp.uint32))
