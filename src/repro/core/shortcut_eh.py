"""Shortcut-EH: extendible hashing accompanied by an asynchronously
maintained shortcut directory (paper §4.1).

Architecture (faithful to the paper):

  * The *traditional* directory (``EHState``) is authoritative; every
    modification is applied to it synchronously and bumps ``trad_version``.
  * A concurrent FIFO queue carries maintenance requests to a *mapper*
    thread polling at a fixed interval (paper: 25 ms):
      - ``update`` requests after bucket splits / content changes, carrying
        the touched slots;
      - ``create`` requests after a directory doubling (the shortcut is
        rebuilt from scratch; pending updates are popped as outdated).
  * The mapper replays requests against the *shortcut view* (the composed
    ``view[i] = buckets[directory[i]]`` of ``rewiring.compose``), then
    eagerly "populates" it (``block_until_ready`` — the page-table
    population analogue) before publishing ``sc_version``.
  * Lookups route through the shortcut only when it is in sync
    (``sc_version == trad_version``) *and* the average fan-in is at most
    ``fan_in_threshold`` (paper: 8) — below that the TLB-thrashing analogue
    (a virtual footprint of 2^g pages vs 2^g pointers + m pages) makes the
    traditional path cheaper.

Delta vs the paper (see DESIGN.md §2): the paper's shortcut *shares*
physical pages, so ordinary in-bucket inserts are instantly visible through
it.  XLA arrays are immutable, so our view is a replica; consequently *every*
insert batch enqueues maintenance for the touched buckets, not only splits.
The asynchronous, version-gated architecture is unchanged.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import extendible_hashing as eh
from repro.core import rewiring


def _next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


# Padded replay-chunk sizes (bounded set => bounded number of jit variants).
_CHUNK_SIZES = (64, 256, 1024, 4096, 16384, 65536)


def _pad_chunk(n: int) -> int:
    for c in _CHUNK_SIZES:
        if n <= c:
            return c
    return _CHUNK_SIZES[-1]


@dataclass
class _Request:
    kind: str            # "create" | "update"
    version: int         # trad_version this request brings the shortcut to
    touched: Optional[np.ndarray] = None  # bucket ids (update only)


@dataclass
class MaintenanceStats:
    creates: int = 0
    updates: int = 0
    slots_remapped: int = 0
    replay_seconds: float = 0.0
    populate_seconds: float = 0.0


class ShortcutEH:
    """Host-side orchestration of the traditional + shortcut directories.

    ``async_mapper=True`` runs the paper's mapper thread; tests and
    deterministic benchmarks use ``async_mapper=False`` + :meth:`pump`.
    """

    def __init__(self, max_global_depth: int, bucket_slots: int,
                 capacity: int, *, fan_in_threshold: float = 8.0,
                 poll_interval: float = 0.025, async_mapper: bool = False):
        self.state = eh.eh_create(max_global_depth, bucket_slots, capacity)
        self.fan_in_threshold = float(fan_in_threshold)
        self.poll_interval = float(poll_interval)
        self.trad_version = 0
        self.sc_version = -1
        self.view_keys: Optional[jax.Array] = None
        self.view_vals: Optional[jax.Array] = None
        self.view_log2 = -1
        self.stats = MaintenanceStats()
        self.routed_shortcut = 0
        self.routed_traditional = 0
        self._queue: "queue.SimpleQueue[_Request]" = queue.SimpleQueue()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._mapper: Optional[threading.Thread] = None
        if async_mapper:
            self._mapper = threading.Thread(
                target=self._mapper_loop, daemon=True, name="eh-mapper")
            self._mapper.start()

    # -- main-thread API ----------------------------------------------------

    def insert(self, keys, values) -> None:
        """Synchronous insert into the traditional index + enqueue
        maintenance (the paper's main-thread behaviour)."""
        keys = jnp.asarray(keys, jnp.uint32)
        values = jnp.asarray(values, jnp.uint32)
        old_g = int(self.state.global_depth)
        with self._lock:
            self.state = eh.eh_insert_many(self.state, keys, values)
            new_g = int(self.state.global_depth)
            self.trad_version += 1
            version = self.trad_version
        if new_g != old_g:
            # doubling: outdated updates are popped before the create request
            self._drain_queue()
            self._queue.put(_Request("create", version))
        else:
            slots = eh.dir_slot(eh.hash_dir(keys), self.state.global_depth)
            touched = np.unique(np.asarray(self.state.directory[slots]))
            self._queue.put(_Request("update", version, touched))

    def lookup(self, keys) -> jax.Array:
        """Route through the shortcut when in sync and fan-in permits."""
        keys = jnp.asarray(keys, jnp.uint32)
        if self.use_shortcut():
            self.routed_shortcut += 1
            return eh.shortcut_lookup_many(
                self.view_keys, self.view_vals,
                self.state.global_depth, keys)
        self.routed_traditional += 1
        return eh.eh_lookup_many(self.state, keys)

    def use_shortcut(self) -> bool:
        return (self.in_sync()
                and self.view_keys is not None
                and self.avg_fan_in() <= self.fan_in_threshold)

    def in_sync(self) -> bool:
        return self.sc_version == self.trad_version

    def avg_fan_in(self) -> float:
        return float((1 << int(self.state.global_depth))
                     / max(1, int(self.state.num_buckets)))

    def versions(self) -> tuple[int, int]:
        return self.trad_version, self.sc_version

    def pump(self, max_requests: int = 1 << 30) -> int:
        """Synchronously process pending maintenance (mapper surrogate)."""
        done = 0
        while done < max_requests:
            batch = self._drain_queue()
            if not batch:
                break
            self._process(batch)
            done += len(batch)
        return done

    def wait_in_sync(self, timeout: float = 30.0) -> bool:
        """Block until the shortcut caught up (async mode)."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self.in_sync() and self._queue.empty():
                return True
            if self._mapper is None:
                self.pump()
            else:
                time.sleep(self.poll_interval / 4)
        return self.in_sync()

    def close(self) -> None:
        self._stop.set()
        if self._mapper is not None:
            self._mapper.join(timeout=5.0)
            self._mapper = None

    # -- mapper side ---------------------------------------------------------

    def _drain_queue(self) -> list[_Request]:
        out = []
        while True:
            try:
                out.append(self._queue.get_nowait())
            except queue.Empty:
                return out

    def _mapper_loop(self) -> None:
        """The paper's mapper thread: poll at a fixed frequency, replay."""
        while not self._stop.is_set():
            batch = self._drain_queue()
            if batch:
                self._process(batch)
            else:
                time.sleep(self.poll_interval)

    def _process(self, batch: list[_Request]) -> None:
        """Replay a drained batch: newest create collapses older updates."""
        creates = [r for r in batch if r.kind == "create"]
        last_create_v = max((r.version for r in creates), default=-1)
        updates = [r for r in batch
                   if r.kind == "update" and r.version > last_create_v]
        target_version = max(r.version for r in batch)

        with self._lock:
            st = self.state
        t0 = time.perf_counter()
        if creates or self.view_keys is None:
            self._replay_create(st)
        if updates:
            touched = np.unique(np.concatenate([u.touched for u in updates]))
            self._replay_update(st, touched)
        t1 = time.perf_counter()
        # Eager page-table population (paper §3.1): make sure no lookup pays
        # the first-touch cost.
        self.view_keys.block_until_ready()
        self.view_vals.block_until_ready()
        t2 = time.perf_counter()
        self.stats.replay_seconds += t1 - t0
        self.stats.populate_seconds += t2 - t1
        self.sc_version = max(self.sc_version, target_version)

    def _replay_create(self, st: eh.EHState) -> None:
        g = int(st.global_depth)
        view_slots = _next_pow2(1 << g)
        self.view_keys, self.view_vals = eh.compose_shortcut(st, view_slots)
        self.view_log2 = view_slots.bit_length() - 1
        self.stats.creates += 1
        self.stats.slots_remapped += view_slots

    def _replay_update(self, st: eh.EHState, touched: np.ndarray) -> None:
        """Remap every view slot whose bucket is in ``touched``.

        Host-side slot discovery (the mapper owns this cost, per §3.3), then
        a padded device scatter — ``rewiring.remap_slots`` is the per-slot
        ``mmap(MAP_SHARED|MAP_FIXED)`` replay; padding remaps slot 0 onto its
        own current bucket (a no-op), mirroring the paper's coalescing of
        neighbouring remaps into fewer calls.
        """
        g = int(st.global_depth)
        dir_np = np.asarray(st.directory[: 1 << g])
        stale = np.isin(dir_np, touched)
        slots = np.nonzero(stale)[0].astype(np.int32)
        if slots.size == 0:
            return
        n = _pad_chunk(slots.size)
        pad = n - slots.size
        slots_p = np.concatenate([slots, np.zeros(pad, np.int32)])
        offsets_p = dir_np[slots_p].astype(np.int32)
        self.view_keys = rewiring.remap_slots(
            self.view_keys, st.bucket_keys, slots_p, offsets_p)
        self.view_vals = rewiring.remap_slots(
            self.view_vals, st.bucket_vals, slots_p, offsets_p)
        self.stats.updates += 1
        self.stats.slots_remapped += int(slots.size)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
