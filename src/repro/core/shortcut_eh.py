"""Shortcut-EH: extendible hashing accompanied by an asynchronously
maintained shortcut directory (paper §4.1).

Architecture (faithful to the paper):

  * The *traditional* directory (``EHState``) is authoritative; every
    modification is applied to it synchronously and bumps the traditional
    version.
  * Maintenance — the FIFO request queue, the polling mapper thread (paper:
    25 ms) / synchronous ``pump()``, create-collapses-older-updates
    batching, eager ``block_until_ready`` population, version gating and
    fan-in routing — is the *generic* shortcut-maintenance runtime
    (``runtime/mapper.ShortcutMapper``, DESIGN.md §4).  This class supplies
    only the two replay callables:
      - ``update`` replay remaps the view slots of touched buckets
        (``rewiring.remap_slots``);
      - ``create`` replay rebuilds the whole view after a directory
        doubling (``extendible_hashing.compose_shortcut``).
  * Lookups route through the shortcut only when it is in sync *and* the
    average fan-in is at most ``fan_in_threshold`` (paper: 8) — below that
    the TLB-thrashing analogue (a virtual footprint of 2^g pages vs 2^g
    pointers + m pages) makes the traditional path cheaper
    (:class:`~repro.runtime.mapper.FanInRouting`).

Delta vs the paper (see DESIGN.md §2): the paper's shortcut *shares*
physical pages, so ordinary in-bucket inserts are instantly visible through
it.  XLA arrays are immutable, so our view is a replica; consequently *every*
insert batch enqueues maintenance for the touched buckets, not only splits.
The asynchronous, version-gated architecture is unchanged.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import extendible_hashing as eh
from repro.core import rewiring
from repro.runtime.mapper import (GLOBAL_VIEW, FanInRouting,
                                  MaintenanceStats, ShortcutMapper)

__all__ = ["ShortcutEH", "MaintenanceStats"]


def _next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


# Padded replay-chunk sizes (bounded set => bounded number of jit variants).
_CHUNK_SIZES = (64, 256, 1024, 4096, 16384, 65536)


def _pad_chunk(n: int) -> int:
    for c in _CHUNK_SIZES:
        if n <= c:
            return c
    return _CHUNK_SIZES[-1]


class ShortcutEH:
    """Thin client of the shortcut-maintenance runtime for the EH index.

    ``async_mapper=True`` runs the paper's mapper thread; tests and
    deterministic benchmarks use ``async_mapper=False`` + :meth:`pump`.
    A custom ``routing`` policy (e.g.
    :class:`~repro.runtime.mapper.HysteresisRouting`) may replace the
    default fan-in threshold rule.
    """

    def __init__(self, max_global_depth: int, bucket_slots: int,
                 capacity: int, *, fan_in_threshold: float = 8.0,
                 poll_interval: float = 0.025, async_mapper: bool = False,
                 routing=None):
        self.state = eh.eh_create(max_global_depth, bucket_slots, capacity)
        # The composed view is ONE atomically-swapped tuple
        # (view_keys, view_vals, view_log2): replays publish a fully
        # built tuple and readers snapshot it once, so a reader racing
        # an async replay can never pair new keys with old vals.
        # When bound to a StackedOperandCache (bind_operand_cache), the
        # stack owns the view instead and _view stays None — per-shard
        # reads become memoized slices of the stack (DESIGN.md §4.4).
        self._view: Optional[tuple] = None
        self._cache = None                  # StackedOperandCache or None
        self._shard = 0
        self._vfam = "eh_view"
        self._tfam = "eh_trad"
        self.mapper = ShortcutMapper(
            replay_create=self._replay_create,
            replay_update=self._replay_update,
            snapshot=lambda: self.state,
            view_arrays=self._view_arrays,
            routing=routing or FanInRouting(float(fan_in_threshold)),
            poll_interval=poll_interval, async_mapper=async_mapper,
            name="eh-mapper")

    # -- delegated bookkeeping (kept for API compatibility) ------------------

    @property
    def stats(self) -> MaintenanceStats:
        return self.mapper.stats

    @property
    def routed_shortcut(self) -> int:
        return self.mapper.routed_shortcut

    @property
    def routed_traditional(self) -> int:
        return self.mapper.routed_fallback

    @property
    def trad_version(self) -> int:
        return self.mapper.trad_version(GLOBAL_VIEW)

    @property
    def sc_version(self) -> int:
        return self.mapper.sc_version(GLOBAL_VIEW)

    @property
    def fan_in_threshold(self):
        return self.mapper.threshold

    @fan_in_threshold.setter
    def fan_in_threshold(self, value: float) -> None:
        self.mapper.threshold = value

    @property
    def poll_interval(self) -> float:
        return self.mapper.poll_interval

    # -- publish epochs (operand-cache keys; runtime/operand_cache.py) -------
    #
    # state_epoch moves with every ``self.state`` reassignment (insert
    # stores the new state, then ``record()`` bumps under the same
    # lock); view_epoch with every replay-batch publication of
    # ``self._view`` (bumped by the runtime before sc_version, so a
    # version gate can never certify a view the cache still sees as
    # clean-but-old).  Read the epoch BEFORE snapshotting the arrays.

    @property
    def state_epoch(self) -> int:
        return self.mapper.trad_epoch

    @property
    def view_epoch(self) -> int:
        return self.mapper.view_epoch

    # -- operand-cache binding (inverted ownership, DESIGN.md §4.4) ----------

    def bind_operand_cache(self, cache, shard: int, *,
                           view_family: str = "eh_view",
                           trad_family: str = "eh_trad") -> None:
        """Hand view ownership to a stacked operand cache.

        After binding, replays publish straight into the owning shard's
        slice of the stacked ``view_family`` (at the mapper's
        ``next_view_epoch``, before ``sc_version`` moves), inserts keep
        ``trad_family`` warm once a lookup built it, and every per-shard
        view read is a memoized slice of the stack — the local ``_view``
        duplicate is deleted.  Bind before any maintenance is enqueued
        (``ShardedShortcutEH`` binds at construction)."""
        self._cache = cache
        self._shard = int(shard)
        self._vfam = view_family
        self._tfam = trad_family
        self._view = None        # the stack is the primary storage now
        self._bound_memo = None

    def _bound_view(self) -> Optional[tuple]:
        """(view_keys, view_vals, view_log2) slices of the stack, or
        None before this shard's first publication.  view_keys/vals are
        padded to the stacked extent; rows past ``2**view_log2`` are
        never indexed (the lookup slots by the shard's own log2).
        Memoized on the cache's slice identity, so the device->host
        ``view_log2`` read happens once per publish, not per lookup."""
        pub = self._cache.published(self._vfam)
        if pub is None or not pub[self._shard]:
            return None
        sl = self._cache.slice_of(self._vfam, self._shard)
        memo = self._bound_memo
        if memo is not None and memo[0] is sl:
            return memo[1]
        view = (sl[0], sl[1], int(sl[2]))
        self._bound_memo = (sl, view)
        return view

    # -- view snapshot (atomic read; see _view comment in __init__) ----------

    def view_snapshot(self) -> Optional[tuple]:
        """One consistent (view_keys, view_vals, view_log2) or None."""
        if self._cache is not None:
            return self._bound_view()
        return self._view

    @property
    def view_keys(self) -> Optional[jax.Array]:
        v = self.view_snapshot()
        return None if v is None else v[0]

    @property
    def view_vals(self) -> Optional[jax.Array]:
        v = self.view_snapshot()
        return None if v is None else v[1]

    @property
    def view_log2(self) -> int:
        v = self.view_snapshot()
        return -1 if v is None else v[2]

    # -- main-thread API ----------------------------------------------------

    def insert(self, keys, values) -> None:
        """Synchronous insert into the traditional index + enqueue
        maintenance (the paper's main-thread behaviour)."""
        keys = jnp.asarray(keys, jnp.uint32)
        values = jnp.asarray(values, jnp.uint32)
        old_g = int(self.state.global_depth)
        with self.mapper.lock:
            self.state = eh.eh_insert_many(self.state, keys, values)
            new_g = int(self.state.global_depth)
            versions = self.mapper.record([GLOBAL_VIEW])
            if self._cache is not None:
                # keep the stacked traditional family warm at publish
                # (write) time — but only once a lookup actually built
                # it; a shortcut-routed steady state never pays for (or
                # holds) the traditional stack at all
                st = self.state
                self._cache.publish_if_present(
                    self._tfam, self._shard,
                    lambda: (st.directory, st.bucket_keys,
                             st.bucket_vals, st.global_depth),
                    epoch=self.mapper.trad_epoch)
        if new_g != old_g:
            # doubling: the runtime pops outdated updates before the create
            self.mapper.submit_create([GLOBAL_VIEW], versions)
        else:
            slots = eh.dir_slot(eh.hash_dir(keys), self.state.global_depth)
            touched = np.unique(np.asarray(self.state.directory[slots]))
            self.mapper.submit_update([GLOBAL_VIEW], versions,
                                      payload=touched)

    def lookup(self, keys) -> jax.Array:
        """Route through the shortcut when in sync and fan-in permits."""
        keys = jnp.asarray(keys, jnp.uint32)
        # gate FIRST, snapshot after: a replay landing in between
        # publishes a strictly newer view, which the gate's verdict
        # still covers; snapshotting first would let the gate certify
        # a stale tuple (async mode could then serve pre-insert data)
        use = self.mapper.gate(self.avg_fan_in(), [GLOBAL_VIEW])
        view = self.view_snapshot()   # single read: the swap is atomic
        use = use and view is not None
        self.mapper.count_route(use)
        if use:
            if self._cache is not None and \
                    jax.default_backend() in ("tpu", "gpu"):
                # resolve straight off the stacked primary: the kernel
                # block-selects the shard via scalar prefetch, so no
                # per-shard slice is ever materialized on device
                from repro.kernels.eh_lookup import stacked_shortcut_lookup
                ops = self._cache.handle(self._vfam)
                return stacked_shortcut_lookup(keys, *ops, self._shard)
            # the tuple's own view_log2, never the live global_depth: a
            # doubling after the snapshot would index past the view.
            # Bound mode pays nothing extra here: view_snapshot is the
            # cache's memoized slice of the stack (zero device work in
            # steady state; the slice cost was paid at publish time).
            return eh.shortcut_lookup_many(view[0], view[1], view[2], keys)
        return eh.eh_lookup_many(self.state, keys)

    def use_shortcut(self) -> bool:
        return (self.view_snapshot() is not None
                and self.mapper.gate(self.avg_fan_in(), [GLOBAL_VIEW]))

    def in_sync(self) -> bool:
        return self.mapper.in_sync([GLOBAL_VIEW])

    def avg_fan_in(self) -> float:
        return float((1 << int(self.state.global_depth))
                     / max(1, int(self.state.num_buckets)))

    def versions(self) -> tuple[int, int]:
        return self.mapper.versions(GLOBAL_VIEW)

    def pump(self, max_requests: int = 1 << 30) -> int:
        """Synchronously process pending maintenance (mapper surrogate)."""
        return self.mapper.pump(max_requests)

    def wait_in_sync(self, timeout: float = 30.0) -> bool:
        """Block until the shortcut caught up (async mode)."""
        return self.mapper.wait_in_sync([GLOBAL_VIEW], timeout)

    def close(self) -> None:
        self.mapper.close()

    # -- replay callables (the only EH-specific maintenance code) ------------

    def _view_arrays(self):
        if self._cache is not None:
            # the stacked family IS the published object readers get
            return self._cache.handle(self._vfam) or ()
        view = self._view
        return () if view is None else view[:2]

    def _publish_view(self, vk, vv, vlog2: int) -> None:
        """Publish one replayed view: bound mode writes the owning
        shard's slice of the stack at the mapper's ``next_view_epoch``
        (zero-copy publish — this runs on the mapper thread, before
        ``sc_version`` moves; a view grown past the stacked extent
        triggers the cache's background re-stack); standalone mode is
        the classic atomic tuple swap."""
        if self._cache is not None:
            self._cache.publish(
                self._vfam, self._shard,
                (vk, vv, jnp.asarray(vlog2, jnp.int32)),
                epoch=self.mapper.next_view_epoch)
            return
        self._view = (vk, vv, vlog2)

    def _replay_create(self, st: eh.EHState, requests) -> None:
        g = int(st.global_depth)
        view_slots = _next_pow2(1 << g)
        vk, vv = eh.compose_shortcut(st, view_slots)
        self._publish_view(vk, vv, view_slots.bit_length() - 1)
        self.mapper.stats.slots_remapped += view_slots

    def _replay_update(self, st: eh.EHState, requests) -> None:
        """Remap every view slot whose bucket is in the merged touched set.

        Host-side slot discovery (the mapper owns this cost, per §3.3), then
        a padded device scatter — ``rewiring.remap_slots`` is the per-slot
        ``mmap(MAP_SHARED|MAP_FIXED)`` replay; padding remaps slot 0 onto its
        own current bucket (a no-op), mirroring the paper's coalescing of
        neighbouring remaps into fewer calls.
        """
        view = self.view_snapshot()
        if view is None:
            # the composed view already reflects the snapshot (and thus
            # these updates); remapping on top would be duplicate work
            self._replay_create(st, requests)
            return
        vk, vv, vlog2 = view
        touched = np.unique(np.concatenate([r.payload for r in requests]))
        g = int(st.global_depth)
        dir_np = np.asarray(st.directory[: 1 << g])
        stale = np.isin(dir_np, touched)
        slots = np.nonzero(stale)[0].astype(np.int32)
        if slots.size == 0:
            if self._cache is not None:
                # no stale slots, but the reader is still owed an epoch:
                # this _process will bump view_epoch and publish its
                # sc versions, and the entry must never lag a
                # gate-certified version
                self._cache.touch(self._vfam, self._shard,
                                  epoch=self.mapper.next_view_epoch)
            return
        n = _pad_chunk(slots.size)
        pad = n - slots.size
        slots_p = np.concatenate([slots, np.zeros(pad, np.int32)])
        offsets_p = dir_np[slots_p].astype(np.int32)
        vk = rewiring.remap_slots(vk, st.bucket_keys, slots_p, offsets_p)
        vv = rewiring.remap_slots(vv, st.bucket_vals, slots_p, offsets_p)
        self._publish_view(vk, vv, vlog2)
        self.mapper.stats.slots_remapped += int(slots.size)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
