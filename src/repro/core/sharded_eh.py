"""Sharded Shortcut-EH: the paper's index partitioned for scale.

The shortcut directory of §4.1 is a single-node construct; this module
partitions the key space by the **top ``log2(N)`` bits of the directory
hash** into N shards, each a *full* :class:`~repro.core.shortcut_eh.ShortcutEH`
(own bucket pool, own traditional directory, own composed view, own
mapper) registered in a :class:`~repro.runtime.shard_group.MapperGroup`.
Because the directory uses MSB indexing, the shard-local directories are
exactly the N contiguous slices of the one big directory the flat index
would have built — the partition is a *refinement*, not a different
structure, which is why a sharded index answers every lookup bit-for-bit
identically to a flat one over the same trace.

What sharding buys (ISSUE/DESIGN.md §4):

  * **bounded per-shard view size** — each shard's directory/view stays
    in the Pallas kernels' VMEM-resident regime (DESIGN.md §2.4) long
    after a flat directory would have outgrown it;
  * **shard-local maintenance** — splits, doublings, create/update
    requests, version gates and route decisions touch exactly one
    shard's mapper; a doubling in shard 3 never collapses shard 5's
    pending updates nor gates its reads (the §5 shootdown concern,
    confined);
  * **one-dispatch batched lookup** — a key batch is bucketized per
    shard with a single stable ``argsort`` pass, padded to a static
    per-shard capacity (bounded size set => bounded jit variants), and
    resolved by ONE ``pallas_call`` whose grid iterates shards
    (``kernels/eh_lookup.sharded_eh_lookup``), then scattered back to
    input order.  The stacked operands are **device-resident**
    (``runtime/operand_cache``, DESIGN.md §4.3): refreshed per dirty
    shard on publish epochs, not re-stacked per call; shards whose
    gates disagree resolve in the same dispatch through the per-shard
    routed kernel (``sharded_routed_lookup``).

``num_shards=1`` degenerates to the flat index: same hash, same routing
law, same maintenance protocol, and ``lookup`` delegates straight to the
inner :class:`ShortcutEH`.

Skew note: within shard s every key shares its top ``shard_bits`` hash
bits, so the first ``shard_bits`` doublings of a shard's directory are
degenerate (both halves of each split land on one side until local
depths exceed ``shard_bits``).  Correctness and the I1–I5 invariants are
untouched; budget ``max_global_depth`` per shard accordingly (the flat
equivalent depth, not depth - shard_bits).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import extendible_hashing as eh
from repro.core.hashing import HASH_C1
from repro.core.shortcut_eh import ShortcutEH
from repro.runtime.mapper import GLOBAL_VIEW, MaintenanceStats
from repro.runtime.operand_cache import StackedOperandCache
# The generic cross-shard batching helpers live with the sharded runtime
# (shared with the KV manager's cross-shard get_context); re-exported
# here because they are part of this module's historical public API.
from repro.runtime.shard_group import (MapperGroup, pad_batch,
                                       partition_by_shard, shard_order)

__all__ = ["ShardedShortcutEH", "partition_by_shard", "shard_of_keys",
           "shard_order"]


def shard_of_keys(keys: np.ndarray, shard_bits: int) -> np.ndarray:
    """Shard index per key: the top ``shard_bits`` of the directory hash
    (host twin of ``hashing.hash_dir`` + MSB slot rule)."""
    if shard_bits == 0:
        return np.zeros(np.asarray(keys).shape, np.int64)
    h = (np.asarray(keys, np.uint64) * np.uint64(HASH_C1)) \
        & np.uint64(0xFFFFFFFF)
    return (h >> np.uint64(32 - shard_bits)).astype(np.int64)


def _trad_parts(states):
    """Operand-cache part builder for the traditional family: one
    shard's ``(directory, bucket_keys, bucket_vals, global_depth)``
    drawn from the consistent per-shard state snapshots.  Shapes are
    static (the directory is allocated at ``max_global_depth``), so this
    family never rebuilds after its first stack.  Built lazily by the
    first traditional-routed batched lookup (pull mode), then kept warm
    by ``insert``'s write-time push — a shortcut-routed steady state
    never holds this stack at all."""
    def parts(s):
        st = states[s]
        return (st.directory, st.bucket_keys, st.bucket_vals,
                st.global_depth)
    return parts


class ShardedShortcutEH:
    """N-way partitioned Shortcut-EH behind the flat index's API.

    Each shard's ``capacity``/``max_global_depth``/``bucket_slots`` equal
    the constructor arguments (capacity is per shard — sizing it as the
    flat index's keeps the sharded index at least as drop-free as the
    flat one under any skew, the precondition for bit-for-bit parity).
    """

    def __init__(self, max_global_depth: int, bucket_slots: int,
                 capacity: int, *, num_shards: int = 1,
                 fan_in_threshold: float = 8.0,
                 poll_interval: float = 0.025, async_mapper: bool = False,
                 routing_factory=None):
        if num_shards < 1 or num_shards & (num_shards - 1):
            raise ValueError(f"num_shards must be a power of two, "
                             f"got {num_shards}")
        self.num_shards = num_shards
        self.shard_bits = num_shards.bit_length() - 1
        self.shards = [
            ShortcutEH(max_global_depth, bucket_slots, capacity,
                       fan_in_threshold=fan_in_threshold,
                       poll_interval=poll_interval,
                       async_mapper=async_mapper,
                       routing=(routing_factory(i) if routing_factory
                                else None))
            for i in range(num_shards)]
        self.group = MapperGroup(
            [s.mapper for s in self.shards],
            router=lambda key: int(shard_of_keys(
                np.asarray([key], np.uint32), self.shard_bits)[0]))
        # primary storage of the stacked lookup operands (families
        # "eh_view" / "eh_trad", DESIGN.md §4.4): replays publish their
        # shard's slice straight into the stack at publish time, so the
        # batched lookup path is an epoch check + handle return with
        # zero device work in steady state, and per-shard views exist
        # only as memoized slices of the stack (no duplicates)
        self.operands = StackedOperandCache(num_shards)
        for i, s in enumerate(self.shards):
            s.bind_operand_cache(self.operands, i)

    # -- routing -------------------------------------------------------------

    def shard_of(self, keys) -> np.ndarray:
        """Vectorized key -> shard index (top hash bits)."""
        return shard_of_keys(np.asarray(keys, np.uint32), self.shard_bits)

    # -- main-thread API ----------------------------------------------------

    def insert(self, keys, values) -> None:
        """Partition the batch and insert into each owning shard.

        Strictly shard-local: each sub-insert takes only its shard's
        lock, bumps only its shard's version, and enqueues maintenance
        only on its shard's queue."""
        keys = np.asarray(keys, np.uint32)
        values = np.asarray(values, np.uint32)
        if self.num_shards == 1:
            self.shards[0].insert(keys, values)
            return
        sid = self.shard_of(keys)
        order, counts, starts = shard_order(sid, self.num_shards)
        for s in range(self.num_shards):
            c = int(counts[s])
            if c:
                idx = order[starts[s]:starts[s] + c]
                self.shards[s].insert(keys[idx], values[idx])

    def lookup(self, keys) -> jax.Array:
        """Routed lookup in input order (each shard independently takes
        its shortcut or traditional path per its own gate).

        Cross-shard batching: one argsort pass, static padded per-shard
        sub-batches (pad lanes are dropped on scatter-back)."""
        keys = np.asarray(keys, np.uint32)
        if keys.size == 0:
            return jnp.zeros((0,), jnp.uint32)
        if self.num_shards == 1:
            return self.shards[0].lookup(keys)
        sid = self.shard_of(keys)
        order, counts, starts = shard_order(sid, self.num_shards)
        cap = pad_batch(int(counts.max()))
        padded, counts, order, rank = partition_by_shard(
            keys, sid, self.num_shards, cap,
            order=order, counts=counts, starts=starts)
        results = np.empty((self.num_shards, cap), np.uint32)
        for s in range(self.num_shards):
            if counts[s]:
                results[s] = np.asarray(self.shards[s].lookup(padded[s]))
        out = np.empty(keys.size, np.uint32)
        out[order] = results[sid[order], rank]
        return jnp.asarray(out)

    def lookup_batched(self, keys, *, tile: int = 256) -> jax.Array:
        """Fused cross-shard lookup: ONE Pallas dispatch for all shards,
        fed from the device-resident operand cache.

        Each shard routes independently (its own gate, its own view):
        an all-shortcut batch takes the shortcut kernel, an all-
        traditional batch the traditional kernel, and a *mixed* batch
        the per-shard routed kernel — still one ``pallas_call``; a
        gate-rejecting shard no longer demotes the others.  The stacked
        operands come from :class:`StackedOperandCache` keyed by the
        shards' publish epochs, so a batch against an unchanged index
        uploads nothing and a replay-churned batch re-uploads only the
        dirty shards' slices.  Returns values in input order."""
        from repro.kernels.eh_lookup import (sharded_eh_lookup,
                                             sharded_routed_lookup,
                                             sharded_shortcut_lookup)
        keys = np.asarray(keys, np.uint32)
        if keys.size == 0:
            # no padding, no operand refresh, no dispatch, no route
            # counters — an empty batch must not touch the device
            return jnp.zeros((0,), jnp.uint32)
        sid = self.shard_of(keys)
        order, counts, starts = shard_order(sid, self.num_shards)
        cap = pad_batch(int(counts.max()))
        padded, counts, order, rank = partition_by_shard(
            keys, sid, self.num_shards, cap,
            order=order, counts=counts, starts=starts)
        # Gate every shard FIRST (each policy decides exactly once — no
        # short-circuit), then read publish epochs/flags: replays
        # publish into the stack BEFORE bumping view_epoch and BEFORE
        # sc_version, so any view a gate certifies is already resident
        # at a covering epoch — get("eh_view", epochs) below is a pure
        # epoch check + handle return, never a patch.  The traditional
        # family stays pull-mode: built lazily here from the per-shard
        # state snapshots (read AFTER the epochs, so an epoch can only
        # under-describe its snapshot), kept warm by insert's push.
        gates = [s.mapper.gate(s.avg_fan_in(), [GLOBAL_VIEW])
                 for s in self.shards]
        view_epochs = [s.view_epoch for s in self.shards]
        state_epochs = [s.state_epoch for s in self.shards]
        states = [s.state for s in self.shards]
        pub = self.operands.published("eh_view")
        shortcut_ok = [g and pub is not None and pub[i]
                       for i, g in enumerate(gates)]
        involved = [int(s) for s in np.nonzero(counts)[0]]
        for s in involved:
            self.group.count_route(shortcut_ok[s], shard=s)
        n_sc = sum(1 for s in involved if shortcut_ok[s])
        keys_dev = jnp.asarray(padded)
        if n_sc:
            view_ops = self.operands.get("eh_view", view_epochs)
        if n_sc < len(involved):
            trad_ops = self.operands.get(
                "eh_trad", state_epochs, _trad_parts(states))
        if n_sc == len(involved):
            res = sharded_shortcut_lookup(keys_dev, *view_ops, tile=tile)
        elif n_sc == 0:
            res = sharded_eh_lookup(keys_dev, *trad_ops, tile=tile)
        else:
            flags = jnp.asarray(
                [0 if ok else 1 for ok in shortcut_ok], jnp.int32)
            res = sharded_routed_lookup(keys_dev, *trad_ops, *view_ops,
                                        flags, tile=tile)
        res = np.asarray(res)
        out = np.empty(keys.size, np.uint32)
        out[order] = res[sid[order], rank]
        return jnp.asarray(out)

    # -- aggregated bookkeeping ----------------------------------------------

    @property
    def stats(self) -> MaintenanceStats:
        return self.group.stats

    def per_shard_stats(self) -> list:
        return self.group.per_shard_stats()

    @property
    def routed_shortcut(self) -> int:
        return self.group.routed_shortcut

    @property
    def routed_traditional(self) -> int:
        return self.group.routed_fallback

    def num_entries(self) -> int:
        return sum(int(eh.eh_num_entries(s.state)) for s in self.shards)

    def avg_fan_in(self) -> float:
        return float(np.mean([s.avg_fan_in() for s in self.shards]))

    def in_sync(self) -> bool:
        return all(s.in_sync() for s in self.shards)

    def pump(self, max_requests: int = 1 << 30) -> int:
        return self.group.pump(max_requests)

    def wait_in_sync(self, timeout: float = 30.0) -> bool:
        return self.group.wait_in_sync(timeout=timeout)

    def close(self) -> None:
        self.group.close()

    # -- verification --------------------------------------------------------

    def check_invariants(self) -> dict:
        """Per-shard structural invariants I1–I5 plus the cross-shard
        S1: every live key is stored in the shard its hash routes to."""
        out = {"ok": True, "errors": [], "shards": []}
        for s, shard in enumerate(self.shards):
            rep = eh.check_invariants(shard.state)
            out["shards"].append(rep)
            if not rep["ok"]:
                out["ok"] = False
                out["errors"] += [f"shard {s}: {e}" for e in rep["errors"]]
            st = shard.state
            nb = int(st.num_buckets)
            bk = np.asarray(st.bucket_keys[:nb])
            live = bk[bk != np.uint32(0xFFFFFFFF)]
            if live.size:
                owners = shard_of_keys(live, self.shard_bits)
                if not (owners == s).all():
                    out["ok"] = False
                    out["errors"].append(
                        f"S1: shard {s} holds foreign keys "
                        f"{live[owners != s][:4].tolist()}")
        return out

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
