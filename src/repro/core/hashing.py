"""Shared hashing and probing primitives (single source of truth).

The paper uses one "lightweight multiplicative hash" for the directory
slot and a second one for the slot within a bucket (§4); the same pair —
Knuth's golden-ratio constants on uint32 — is used by every structure in
this repo for comparability (§4.2).  Before this module existed the
constants and the masked linear-probe logic were duplicated across the
XLA core (``core/extendible_hashing.py``), the Pallas kernels
(``kernels/eh_lookup.py``) and the baselines (``core/baselines.py``);
they now live here and *only* here.

Two flavours of each constant are exported:

  * plain Python ints (``HASH_C1`` …) — safe to close over inside Pallas
    kernels (a module-level traced constant would be captured by the
    kernel, which Pallas forbids); cast at use sites.
  * ``jnp.uint32`` values (``EMPTY_KEY``, ``MISS``) for the XLA paths.

Probing follows the paper's evaluation setup: open addressing / linear
probing with the *first-empty-slot-terminates* rule — a hit after an
empty slot is a ghost from a different probe chain and must be ignored.
"""
from __future__ import annotations

import jax.numpy as jnp

# -- the constants (defined here and nowhere else) ---------------------------

HASH_C1: int = 2654435761          # Knuth multiplicative (directory hash)
HASH_C2: int = 0x9E3779B1          # golden-ratio variant (bucket-slot hash)
EMPTY_SENTINEL: int = 0xFFFFFFFF   # slot unused (python int, kernel-safe)
MISS_SENTINEL: int = 0xFFFFFFFF    # lookup miss marker (python int)

EMPTY_KEY = jnp.uint32(EMPTY_SENTINEL)
MISS = jnp.uint32(MISS_SENTINEL)


# -- hashes ------------------------------------------------------------------

def hash_dir(key: jnp.ndarray) -> jnp.ndarray:
    """Primary multiplicative hash; directories use its most significant
    bits (the precondition for contiguous fan-in ranges, §4.1)."""
    return (key.astype(jnp.uint32) * jnp.uint32(HASH_C1)).astype(jnp.uint32)


def hash_bucket(key: jnp.ndarray) -> jnp.ndarray:
    """Secondary hash for the slot within a bucket page."""
    k = key.astype(jnp.uint32) * jnp.uint32(HASH_C2)
    return (k ^ (k >> jnp.uint32(16))).astype(jnp.uint32)


def hash_dir_host(key: int) -> int:
    """Host-side (numpy-free) twin of :func:`hash_dir` for invariant
    checks and host-built views."""
    return (int(key) * HASH_C1) & 0xFFFFFFFF


def dir_slot(h: jnp.ndarray, depth: jnp.ndarray) -> jnp.ndarray:
    """Most-significant-bit slot of hash ``h`` in a table of ``2**depth``
    entries; depth 0 => single slot 0.  (uint32 >> 32 is undefined, so
    depth 0 is guarded.)"""
    d = depth.astype(jnp.uint32) if hasattr(depth, "astype") \
        else jnp.uint32(depth)
    return jnp.where(d == jnp.uint32(0), jnp.uint32(0),
                     h >> (jnp.uint32(32) - d)).astype(jnp.int32)


# -- probe-sequence generators ----------------------------------------------

def probe_positions(key: jnp.ndarray, slots: int) -> jnp.ndarray:
    """Full cyclic probe sequence over a bucket row of ``slots`` entries,
    starting at the secondary hash."""
    start = hash_bucket(key) % jnp.uint32(slots)
    return ((start + jnp.arange(slots, dtype=jnp.uint32))
            % jnp.uint32(slots)).astype(jnp.int32)


def window_positions(h: jnp.ndarray, size_log2: jnp.ndarray,
                     window: int) -> jnp.ndarray:
    """Linear probe window of ``window`` slots from the home slot of
    hash ``h`` in an active table prefix of ``2**size_log2`` entries."""
    size = jnp.int32(1) << size_log2
    home = dir_slot(h, size_log2)
    return (home + jnp.arange(window, dtype=jnp.int32)) % size


# -- masked probes (the duplicated core, now shared) -------------------------

def probe_hit(probed: jnp.ndarray, key: jnp.ndarray):
    """Find ``key`` in the probed key sequence.

    Returns ``(found, idx)`` where ``idx`` indexes *into the probe
    sequence*; a hit after the first EMPTY slot is ignored (linear
    probing terminates at the first empty slot)."""
    hit = probed == key.astype(jnp.uint32)
    # sentinel built at use site: these helpers trace inside Pallas
    # kernels, where closing over a module-level concrete array is an
    # illegal captured constant
    empties = probed == jnp.uint32(EMPTY_SENTINEL)
    before = jnp.cumsum(empties.astype(jnp.int32)) - empties.astype(jnp.int32)
    live = hit & (before == 0)
    return jnp.any(live), jnp.argmax(live)


def probe_slot(probed: jnp.ndarray, key: jnp.ndarray):
    """Find the insert slot for ``key``: the first position that either
    already holds ``key`` (overwrite) or is EMPTY.

    Returns ``(ok, idx)`` with ``idx`` into the probe sequence; ``ok`` is
    False when the probed window is full and the key absent."""
    usable = (probed == key.astype(jnp.uint32)) \
        | (probed == jnp.uint32(EMPTY_SENTINEL))
    return jnp.any(usable), jnp.argmax(usable)
