"""Extendible hashing (Fagin et al. [3]) as a pure-functional, jittable JAX
data structure — the paper's showcase index (§4).

Layout (all arrays statically sized, validity tracked by scalars):

  * ``directory``    -- (max_dir,) int32; the first ``2**global_depth`` slots
                        are valid and hold bucket ids.  Indexed by the
                        *most significant* ``global_depth`` bits of the hash
                        (as in the paper), so all slots referencing one bucket
                        form a contiguous range — the precondition for
                        coalesced remapping (``rewiring.remap_range``).
  * ``bucket_keys``/``bucket_vals`` -- (capacity, bucket_slots); a bucket is a
                        4 KB page analogue.  Open addressing / linear probing
                        *within* a bucket, as in the paper's evaluation.
  * ``local_depth``  -- (capacity,) int32 per-bucket depth.
  * ``counts``       -- (capacity,) int32 live entries per bucket.
  * ``num_buckets``  -- () int32 bump-allocator high-water mark (EH never
                        frees buckets; the KV-cache layer exercises the pool's
                        free ring instead).

Hashing: the paper uses one "lightweight multiplicative hash" for the
directory slot and a second one for the bucket slot; the constants and
probe primitives are shared with the kernels and baselines via
``core/hashing.py`` (``hash_dir``/``hash_bucket``/``dir_slot`` are
re-exported here for backwards compatibility).

All mutating ops return a new state (functional); batched insertion is a
``lax.scan``, batched lookup a ``vmap``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.core.hashing import (EMPTY_KEY, MISS,  # noqa: F401  (re-export)
                                dir_slot, hash_bucket, hash_dir)


class EHState(NamedTuple):
    directory: jax.Array     # (max_dir,) int32 bucket ids
    bucket_keys: jax.Array   # (capacity, bucket_slots) uint32
    bucket_vals: jax.Array   # (capacity, bucket_slots) uint32
    counts: jax.Array        # (capacity,) int32
    local_depth: jax.Array   # (capacity,) int32
    global_depth: jax.Array  # () int32
    num_buckets: jax.Array   # () int32
    dropped: jax.Array       # () int32  inserts refused (capacity exhausted)

    @property
    def max_global_depth(self) -> int:
        return int(self.directory.shape[0]).bit_length() - 1

    @property
    def capacity(self) -> int:
        return self.bucket_keys.shape[0]

    @property
    def bucket_slots(self) -> int:
        return self.bucket_keys.shape[1]


def eh_create(max_global_depth: int, bucket_slots: int,
              capacity: int) -> EHState:
    """One empty bucket, one directory slot (the paper's 4 KB start state)."""
    assert capacity >= 1
    return EHState(
        directory=jnp.zeros((1 << max_global_depth,), jnp.int32),
        bucket_keys=jnp.full((capacity, bucket_slots), EMPTY_KEY, jnp.uint32),
        bucket_vals=jnp.zeros((capacity, bucket_slots), jnp.uint32),
        counts=jnp.zeros((capacity,), jnp.int32),
        local_depth=jnp.zeros((capacity,), jnp.int32),
        global_depth=jnp.zeros((), jnp.int32),
        num_buckets=jnp.ones((), jnp.int32),
        dropped=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Intra-bucket open addressing (vectorized probe, no loops).
# ---------------------------------------------------------------------------

def bucket_find(keys_row: jax.Array, key: jax.Array) -> jax.Array:
    """Probe a bucket row; return slot index of ``key`` or -1."""
    pos = hashing.probe_positions(key, keys_row.shape[0])
    found, j = hashing.probe_hit(keys_row[pos], key)
    return jnp.where(found, pos[j], -1)


def bucket_put(keys_row: jax.Array, vals_row: jax.Array, key: jax.Array,
               value: jax.Array):
    """Insert/overwrite (key,value) in a bucket row.

    Returns (keys_row, vals_row, inserted_new, ok):
      inserted_new -- 1 if a fresh slot was consumed (count must grow)
      ok           -- 0 if the bucket was full and key absent
    """
    pos = hashing.probe_positions(key, keys_row.shape[0])
    ok, j = hashing.probe_slot(keys_row[pos], key)
    idx = pos[j]
    was_empty = keys_row[idx] == EMPTY_KEY
    keys_row = keys_row.at[idx].set(
        jnp.where(ok, key.astype(jnp.uint32), keys_row[idx]))
    vals_row = vals_row.at[idx].set(
        jnp.where(ok, value.astype(jnp.uint32), vals_row[idx]))
    inserted_new = (ok & was_empty).astype(jnp.int32)
    return keys_row, vals_row, inserted_new, ok


# ---------------------------------------------------------------------------
# Directory maintenance: doubling and bucket split.
# ---------------------------------------------------------------------------

def _double_directory(st: EHState) -> EHState:
    """MSB indexing: each valid slot i fans out to slots 2i, 2i+1."""
    max_dir = st.directory.shape[0]
    idx = jnp.arange(max_dir, dtype=jnp.int32)
    grown = st.directory[idx >> 1]
    valid = idx < (1 << (st.global_depth + 1))
    return st._replace(
        directory=jnp.where(valid, grown, st.directory),
        global_depth=st.global_depth + 1,
    )


def _split_bucket(st: EHState, h: jax.Array) -> EHState:
    """Split the bucket addressed by hash ``h`` (paper Fig. 6 step)."""
    st = jax.lax.cond(
        st.local_depth[st.directory[dir_slot(h, st.global_depth)]]
        == st.global_depth,
        _double_directory, lambda s: s, st)

    g = st.global_depth
    slot = dir_slot(h, g)
    b = st.directory[slot]
    l = st.local_depth[b]
    b2 = st.num_buckets  # bump allocation

    # Redistribute entries of b between b and b2 on hash bit (l+1) from the top.
    old_keys = st.bucket_keys[b]
    old_vals = st.bucket_vals[b]
    slots = st.bucket_slots
    empty_row = jnp.full((slots,), EMPTY_KEY, jnp.uint32)
    zero_row = jnp.zeros((slots,), jnp.uint32)

    def redistribute(i, carry):
        k0, v0, c0, k1, v1, c1 = carry
        key = old_keys[i]
        val = old_vals[i]
        live = key != EMPTY_KEY
        bit = (hash_dir(key) >> (jnp.uint32(31) - l.astype(jnp.uint32))) \
            & jnp.uint32(1)
        to_new = live & (bit == 1)
        to_old = live & (bit == 0)
        nk0, nv0, inew0, _ = bucket_put(k0, v0, key, val)
        nk1, nv1, inew1, _ = bucket_put(k1, v1, key, val)
        k0 = jnp.where(to_old, nk0, k0)
        v0 = jnp.where(to_old, nv0, v0)
        c0 = c0 + jnp.where(to_old, inew0, 0)
        k1 = jnp.where(to_new, nk1, k1)
        v1 = jnp.where(to_new, nv1, v1)
        c1 = c1 + jnp.where(to_new, inew1, 0)
        return k0, v0, c0, k1, v1, c1

    k0, v0, c0, k1, v1, c1 = jax.lax.fori_loop(
        0, slots, redistribute,
        (empty_row, zero_row, jnp.int32(0), empty_row, zero_row, jnp.int32(0)))

    # Directory range [start, start+2^(g-l)) pointed at b; upper half -> b2.
    shift = (g - l).astype(jnp.uint32)
    start = (slot >> shift) << shift
    length = jnp.int32(1) << (g - l)
    half = length >> 1
    idx = jnp.arange(st.directory.shape[0], dtype=jnp.int32)
    in_upper = (idx >= start + half) & (idx < start + length)
    return st._replace(
        directory=jnp.where(in_upper, b2, st.directory),
        bucket_keys=st.bucket_keys.at[b].set(k0).at[b2].set(k1),
        bucket_vals=st.bucket_vals.at[b].set(v0).at[b2].set(v1),
        counts=st.counts.at[b].set(c0).at[b2].set(c1),
        local_depth=st.local_depth.at[b].set(l + 1).at[b2].set(l + 1),
        num_buckets=st.num_buckets + 1,
    )


# ---------------------------------------------------------------------------
# Public ops.
# ---------------------------------------------------------------------------

def eh_insert(st: EHState, key: jax.Array, value: jax.Array) -> EHState:
    """Insert (key, value); splits (possibly cascading) handled in-line."""
    h = hash_dir(key)

    def needs_split(s: EHState):
        b = s.directory[dir_slot(h, s.global_depth)]
        full = s.counts[b] >= s.bucket_slots
        present = bucket_find(s.bucket_keys[b], key) >= 0
        can_grow = (s.num_buckets < s.capacity) & \
            ((s.local_depth[b] < s.global_depth) |
             (s.global_depth < s.max_global_depth))
        return full & ~present & can_grow

    st = jax.lax.while_loop(needs_split, lambda s: _split_bucket(s, h), st)

    b = st.directory[dir_slot(h, st.global_depth)]
    nk, nv, inserted_new, ok = bucket_put(
        st.bucket_keys[b], st.bucket_vals[b], key, value)
    return st._replace(
        bucket_keys=st.bucket_keys.at[b].set(
            jnp.where(ok, nk, st.bucket_keys[b])),
        bucket_vals=st.bucket_vals.at[b].set(
            jnp.where(ok, nv, st.bucket_vals[b])),
        counts=st.counts.at[b].add(inserted_new),
        dropped=st.dropped + (1 - ok.astype(jnp.int32)),
    )


@jax.jit
def eh_insert_many(st: EHState, keys: jax.Array,
                   values: jax.Array) -> EHState:
    """Sequential batch insert (splits serialize inserts by nature)."""
    def body(s, kv):
        return eh_insert(s, kv[0], kv[1]), None
    st, _ = jax.lax.scan(body, st, jnp.stack(
        [keys.astype(jnp.uint32), values.astype(jnp.uint32)], axis=1))
    return st


def eh_lookup(st: EHState, key: jax.Array) -> jax.Array:
    """Traditional path: directory gather -> bucket gather -> probe."""
    b = st.directory[dir_slot(hash_dir(key), st.global_depth)]
    idx = bucket_find(st.bucket_keys[b], key)
    return jnp.where(idx >= 0, st.bucket_vals[b][idx], MISS)


@jax.jit
def eh_lookup_many(st: EHState, keys: jax.Array) -> jax.Array:
    return jax.vmap(lambda k: eh_lookup(st, k))(keys.astype(jnp.uint32))


# ---------------------------------------------------------------------------
# Shortcut path: lookups against a pre-composed view (rewiring.compose of the
# bucket pages by the directory).  One indirection instead of two.
# ---------------------------------------------------------------------------

def shortcut_lookup(view_keys: jax.Array, view_vals: jax.Array,
                    global_depth: jax.Array, key: jax.Array) -> jax.Array:
    """Lookup through the composed view: slot arithmetic + one gather."""
    slot = dir_slot(hash_dir(key), global_depth)
    idx = bucket_find(view_keys[slot], key)
    return jnp.where(idx >= 0, view_vals[slot][idx], MISS)


@jax.jit
def shortcut_lookup_many(view_keys: jax.Array, view_vals: jax.Array,
                         global_depth: jax.Array,
                         keys: jax.Array) -> jax.Array:
    return jax.vmap(
        lambda k: shortcut_lookup(view_keys, view_vals, global_depth, k)
    )(keys.astype(jnp.uint32))


@functools.partial(jax.jit, static_argnames=("view_slots",))
def compose_shortcut(st: EHState, view_slots: int):
    """Create-request replay: materialize (view_keys, view_vals) for the first
    ``view_slots`` directory slots (a static power of two >= 2**global_depth).

    This is the expensive one-shot 'mmap loop' of the paper's step (2); the
    ShortcutEH wrapper runs it asynchronously.
    """
    idx = jnp.arange(view_slots, dtype=jnp.int32)
    valid = idx < (1 << st.global_depth)
    src = jnp.where(valid, st.directory[:view_slots], 0)
    return st.bucket_keys[src], st.bucket_vals[src]


# ---------------------------------------------------------------------------
# Introspection used by routing and tests.
# ---------------------------------------------------------------------------

def avg_fan_in(st: EHState) -> jax.Array:
    """Average number of directory slots per bucket = 2^g / #buckets."""
    return (jnp.int32(1) << st.global_depth).astype(jnp.float32) \
        / st.num_buckets.astype(jnp.float32)


def eh_num_entries(st: EHState) -> jax.Array:
    return jnp.sum(st.counts)


def check_invariants(st: EHState) -> dict:
    """Host-side invariant checks (used by property tests).

    I1: every valid directory slot points to an allocated bucket.
    I2: bucket b with local depth l is referenced by exactly 2^(g-l)
        *contiguous* slots whose top-l hash bits are constant.
    I3: local_depth <= global_depth for all allocated buckets.
    I4: every live key is stored in the bucket its hash addresses.
    I5: counts match the number of non-empty slots.
    """
    import numpy as np
    g = int(st.global_depth)
    nd = 1 << g
    directory = np.asarray(st.directory[:nd])
    nb = int(st.num_buckets)
    out = {"ok": True, "errors": []}

    def fail(msg):
        out["ok"] = False
        out["errors"].append(msg)

    if not ((directory >= 0) & (directory < nb)).all():
        fail("I1: dangling directory slot")
    ld = np.asarray(st.local_depth[:nb])
    if (ld > g).any():
        fail("I3: local depth exceeds global depth")
    ref_counts = {}
    for slot, b in enumerate(directory):
        ref_counts.setdefault(int(b), []).append(slot)
    for b, slots in ref_counts.items():
        expect = 1 << (g - int(ld[b]))
        if len(slots) != expect:
            fail(f"I2: bucket {b} referenced {len(slots)}x, expect {expect}")
        if slots != list(range(slots[0], slots[0] + len(slots))):
            fail(f"I2: bucket {b} slots not contiguous")
    keys = np.asarray(st.bucket_keys[:nb])
    counts = np.asarray(st.counts[:nb])
    live = keys != np.uint32(hashing.EMPTY_SENTINEL)
    if not (live.sum(axis=1) == counts).all():
        fail("I5: counts mismatch")
    for b in range(nb):
        for k in keys[b][live[b]]:
            h = hashing.hash_dir_host(int(k))
            slot = h >> (32 - g) if g > 0 else 0
            if int(directory[slot]) != b:
                fail(f"I4: key {k} misplaced (bucket {b}, slot {slot})")
    return out
