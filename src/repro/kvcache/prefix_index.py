"""Prefix-cache index: the paper's EH index used AS the serving lookup
structure (DESIGN.md §3) — completing the loop between the two layers.

Prefix caching deduplicates KV blocks across requests that share a prompt
prefix (system prompts, few-shot headers).  The lookup structure maps
``hash(token-block content, parent-chain)`` -> physical KV block id: a
dynamic hash index with exactly the paper's profile — unknown final size,
lookup-heavy, bursty inserts when new prompts arrive — so it IS a
Shortcut-EH: synchronous traditional directory, async shortcut directory,
version gating, fan-in routing.

Chain hashing: block i's key folds its content hash into the parent's
key (a Merkle chain), so a hit at block i implies the whole prefix
[0, i] matches — single probe per block, no token re-comparison.

On top of the per-block EH index sits a *second* shortcut (DESIGN.md §4):
the **prefix → block-table shortcut**.  The authoritative path resolves a
request one chain key at a time (one probe per block).  The shortcut view
pre-composes ``final chain key -> whole block table`` into an
open-addressed device table, so a request whose full prefix is cached
resolves in ONE probe instead of ``n_blocks`` — the same
"skip the pointer chase" move, one level up.  It is maintained by its own
:class:`~repro.runtime.mapper.ShortcutMapper` (the third client of the
generic runtime): inserts enqueue *update* requests (write one row),
occupancy-driven table growth enqueues *create* requests (rebuild), and
routing engages once the mean chain length makes the multi-probe walk
expensive enough to beat.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core import hashing
from repro.core.shortcut_eh import ShortcutEH
from repro.runtime.mapper import (GLOBAL_VIEW, FragmentationRouting,
                                  ShortcutMapper)

_MISS = 0xFFFFFFFF
_FNV_PRIME = 1099511628211
_FNV_OFF = 14695981039346656037
_MASK64 = (1 << 64) - 1


def _fnv1a(data: np.ndarray, seed: int) -> int:
    """FNV-1a over uint64 words with explicit masked Python-int arithmetic
    (numpy uint64 multiplies emit RuntimeWarning on the intended
    wraparound; Python ints make the mod-2^64 semantics explicit and
    warning-free)."""
    h = seed if seed else _FNV_OFF
    for b in np.asarray(data, np.uint64).tolist():
        h = ((h ^ b) * _FNV_PRIME) & _MASK64
    return h


class PrefixCacheIndex:
    """Maps token-block prefixes to physical KV blocks via Shortcut-EH,
    plus a whole-prefix shortcut over the final chain key."""

    def __init__(self, block_size: int, *, max_global_depth: int = 16,
                 bucket_slots: int = 64, capacity: int = 4096,
                 async_mapper: bool = False, table_log2: int = 8,
                 chain_threshold: float = 2.0):
        self.block_size = block_size
        self.index = ShortcutEH(
            max_global_depth=max_global_depth, bucket_slots=bucket_slots,
            capacity=capacity, async_mapper=async_mapper)
        self.hits = 0
        self.misses = 0
        # -- prefix -> block-table shortcut (third runtime client) ----------
        # authoritative side: every registered chain, final key -> blocks
        self._chains: dict[int, tuple[int, ...]] = {}
        self._chain_len_total = 0        # running sum for O(1) mean length
        self._table_log2 = int(table_log2)
        self._max_chain = 1
        # The view is ONE atomically-swapped tuple (keys (T,) uint32,
        # blocks (T, max_chain) int32, lens (T,) int32, table_log2) of
        # host numpy arrays: replays publish a fully-built tuple and
        # readers snapshot it once, so the async mapper thread can never
        # expose torn state.  Host arrays because the view is only ever
        # probed host-side (one slot per lookup).
        self._view: Optional[tuple] = None
        self.prefix_mapper = ShortcutMapper(
            replay_create=self._replay_create,
            replay_update=self._replay_update,
            snapshot=lambda: (dict(self._chains), self._table_log2,
                              self._max_chain),
            view_arrays=self._view_arrays,
            routing=FragmentationRouting(float(chain_threshold)),
            async_mapper=async_mapper, name="prefix-mapper")

    # -- key derivation ------------------------------------------------------

    def chain_keys(self, tokens: Sequence[int]) -> np.ndarray:
        """uint32 keys for each complete block of ``tokens`` (Merkle
        chain: key_i commits to blocks [0, i])."""
        toks = np.asarray(tokens, np.uint64)
        n_blocks = len(toks) // self.block_size
        keys = np.empty((n_blocks,), np.uint32)
        h = 0
        for i in range(n_blocks):
            blk = toks[i * self.block_size:(i + 1) * self.block_size]
            h = _fnv1a(blk, h)
            # avoid the EMPTY/MISS sentinel
            k = h & 0xFFFFFFFF
            keys[i] = np.uint32(1) if k in (0, _MISS) else np.uint32(k)
        return keys

    # -- serving API ---------------------------------------------------------

    def match_prefix(self, tokens: Sequence[int]) -> tuple[int, list]:
        """Longest cached prefix of ``tokens``.

        Returns (num_cached_tokens, [physical block ids]) — the serving
        layer copies/aliases these blocks instead of re-prefilling.

        Fast path: when the prefix shortcut is in sync and routed, the
        *final* chain key is probed once against the composed
        prefix -> block-table view; a hit returns the whole table without
        walking the chain.  A miss (or an out-of-sync/unprofitable view)
        falls back to the authoritative per-block walk.
        """
        keys = self.chain_keys(tokens)
        if keys.size == 0:
            return 0, []
        if self.prefix_mapper.gate(self._mean_chain_len(), [GLOBAL_VIEW]):
            blocks = self._shortcut_match(int(keys[-1]))
            if blocks is not None:
                self.prefix_mapper.count_route(True)
                self.hits += 1
                return len(blocks) * self.block_size, list(blocks)
        self.prefix_mapper.count_route(False)
        vals = np.asarray(self.index.lookup(keys))
        blocks = []
        for v in vals:
            if int(v) == _MISS:
                break
            blocks.append(int(v))
        if blocks:
            self.hits += 1
        else:
            self.misses += 1
        return len(blocks) * self.block_size, blocks

    def insert_prefix(self, tokens: Sequence[int],
                      block_ids: Sequence[int]) -> int:
        """Register the (complete) blocks of a finished prefill.

        Returns the number of blocks registered.  Maintenance of both
        shortcut directories is asynchronous as always (``pump()`` or the
        mapper threads replay it)."""
        keys = self.chain_keys(tokens)
        n = min(len(keys), len(block_ids))
        if n == 0:
            return 0
        self.index.insert(keys[:n], np.asarray(block_ids[:n], np.uint32))
        # authoritative chain registry + shortcut maintenance requests:
        # every intermediate chain [0, i] is a valid full prefix.
        new_rows = []
        with self.prefix_mapper.lock:
            for i in range(n):
                key = int(keys[i])
                chain = tuple(int(b) for b in block_ids[:i + 1])
                old = self._chains.get(key)
                if old is not None:
                    self._chain_len_total -= len(old)
                self._chains[key] = chain
                self._chain_len_total += len(chain)
                new_rows.append((key, chain))
            self._max_chain = max(self._max_chain,
                                  max(len(c) for _, c in new_rows))
            grow = len(self._chains) * 2 > (1 << self._table_log2)
            while len(self._chains) * 2 > (1 << self._table_log2):
                self._table_log2 += 1    # bulk inserts may need > 1 doubling
            versions = self.prefix_mapper.record([GLOBAL_VIEW])
        view = self._view
        needs_create = (grow or view is None
                        or view[1].shape[1] < self._max_chain)
        if needs_create:
            self.prefix_mapper.submit_create([GLOBAL_VIEW], versions)
        else:
            self.prefix_mapper.submit_update([GLOBAL_VIEW], versions,
                                             payload=new_rows)
        return n

    def pump(self):
        self.index.pump()
        self.prefix_mapper.pump()

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "in_sync": self.index.in_sync(),
                "fan_in": self.index.avg_fan_in(),
                "routed_shortcut": self.index.routed_shortcut,
                "routed_traditional": self.index.routed_traditional,
                "prefix_in_sync": self.prefix_mapper.in_sync([GLOBAL_VIEW]),
                "prefix_routed_shortcut": self.prefix_mapper.routed_shortcut,
                "prefix_routed_walk": self.prefix_mapper.routed_fallback}

    def close(self):
        self.index.close()
        self.prefix_mapper.close()

    # -- prefix-shortcut internals -------------------------------------------

    def _mean_chain_len(self) -> float:
        if not self._chains:
            return 0.0
        return self._chain_len_total / len(self._chains)

    def _probe_seq(self, key: int, table_log2: int) -> np.ndarray:
        """Host-side linear probe window (same MSB home slot + window rule
        as ``core/hashing.py``; replays and lookups must agree)."""
        size = 1 << table_log2
        home = (hashing.hash_dir_host(key) >> (32 - table_log2)) \
            if table_log2 > 0 else 0
        return (home + np.arange(min(32, size))) % size

    def _insert_row(self, vk: np.ndarray, vb: np.ndarray, vl: np.ndarray,
                    table_log2: int, key: int, chain: tuple) -> int:
        """Probe-insert one (key, chain) row: first matching-or-empty slot.
        Shared by create and update replays so the probe rule cannot
        drift between them (and from :meth:`_shortcut_match`)."""
        for p in self._probe_seq(key, table_log2):
            if vk[p] == np.uint32(hashing.EMPTY_SENTINEL) \
                    or vk[p] == np.uint32(key):
                vk[p] = np.uint32(key)
                vb[p, :len(chain)] = chain
                vl[p] = len(chain)
                return 1
        return 0    # window full: row dropped, lookups fall back (miss)

    def _shortcut_match(self, key: int) -> Optional[tuple]:
        """One probe of the composed view; None on miss."""
        view = self._view      # single read: the replay swap is atomic
        if view is None:
            return None
        vk, vb, vl, table_log2 = view
        pos = self._probe_seq(key, table_log2)
        probed = vk[pos]
        hit = np.nonzero(probed == np.uint32(key))[0]
        stop = np.nonzero(probed == np.uint32(hashing.EMPTY_SENTINEL))[0]
        if hit.size == 0 or (stop.size and stop[0] < hit[0]):
            return None
        slot = int(pos[hit[0]])
        return tuple(int(b) for b in vb[slot, :int(vl[slot])])

    def _view_arrays(self):
        return ()   # host numpy view: resident by construction

    def _replay_create(self, snap, requests) -> None:
        """Rebuild the whole table from the authoritative chain registry
        (the create-request 'mmap loop'), then publish it atomically."""
        chains, table_log2, max_chain = snap
        size = 1 << table_log2
        vk = np.full((size,), hashing.EMPTY_SENTINEL, np.uint32)
        vb = np.full((size, max_chain), -1, np.int32)
        vl = np.zeros((size,), np.int32)
        for key, chain in chains.items():
            self._insert_row(vk, vb, vl, table_log2, key, chain)
        self._view = (vk, vb, vl, table_log2)
        self.prefix_mapper.stats.slots_remapped += len(chains)

    def _replay_update(self, snap, requests) -> None:
        """Write the new rows into a copy of the view (per-slot remap),
        then publish the copy atomically."""
        view = self._view
        if view is None:
            self._replay_create(snap, requests)
            return
        vk, vb, vl, table_log2 = (np.array(view[0]), np.array(view[1]),
                                  np.array(view[2]), view[3])
        n_rows = 0
        for r in requests:
            for key, chain in r.payload:
                n_rows += self._insert_row(vk, vb, vl, table_log2,
                                           key, chain)
        self._view = (vk, vb, vl, table_log2)
        self.prefix_mapper.stats.slots_remapped += n_rows

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
