"""Prefix-cache index: the paper's EH index used AS the serving lookup
structure (DESIGN.md §3) — completing the loop between the two layers.

Prefix caching deduplicates KV blocks across requests that share a prompt
prefix (system prompts, few-shot headers).  The lookup structure maps
``hash(token-block content, parent-chain)`` -> physical KV block id: a
dynamic hash index with exactly the paper's profile — unknown final size,
lookup-heavy, bursty inserts when new prompts arrive — so it IS a
Shortcut-EH: synchronous traditional directory, async shortcut directory,
version gating, fan-in routing.

Chain hashing: block i's key folds its content hash into the parent's
key (a Merkle chain), so a hit at block i implies the whole prefix
[0, i] matches — single probe per block, no token re-comparison.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.shortcut_eh import ShortcutEH

_MISS = 0xFFFFFFFF
_FNV_PRIME = np.uint64(1099511628211)
_FNV_OFF = np.uint64(14695981039346656037)


def _fnv1a(data: np.ndarray, seed: np.uint64) -> np.uint64:
    h = seed if seed else _FNV_OFF
    for b in np.asarray(data, np.uint64):
        h = np.uint64((h ^ b) * _FNV_PRIME)
    return h


class PrefixCacheIndex:
    """Maps token-block prefixes to physical KV blocks via Shortcut-EH."""

    def __init__(self, block_size: int, *, max_global_depth: int = 16,
                 bucket_slots: int = 64, capacity: int = 4096,
                 async_mapper: bool = False):
        self.block_size = block_size
        self.index = ShortcutEH(
            max_global_depth=max_global_depth, bucket_slots=bucket_slots,
            capacity=capacity, async_mapper=async_mapper)
        self.hits = 0
        self.misses = 0

    # -- key derivation ------------------------------------------------------

    def chain_keys(self, tokens: Sequence[int]) -> np.ndarray:
        """uint32 keys for each complete block of ``tokens`` (Merkle
        chain: key_i commits to blocks [0, i])."""
        toks = np.asarray(tokens, np.uint64)
        n_blocks = len(toks) // self.block_size
        keys = np.empty((n_blocks,), np.uint32)
        h = np.uint64(0)
        for i in range(n_blocks):
            blk = toks[i * self.block_size:(i + 1) * self.block_size]
            h = _fnv1a(blk, h)
            # avoid the EMPTY/MISS sentinel
            k = np.uint32(h & np.uint64(0xFFFFFFFF))
            keys[i] = np.uint32(1) if k in (0, _MISS) else k
        return keys

    # -- serving API ---------------------------------------------------------

    def match_prefix(self, tokens: Sequence[int]) -> tuple[int, list]:
        """Longest cached prefix of ``tokens``.

        Returns (num_cached_tokens, [physical block ids]) — the serving
        layer copies/aliases these blocks instead of re-prefilling."""
        keys = self.chain_keys(tokens)
        if keys.size == 0:
            return 0, []
        vals = np.asarray(self.index.lookup(keys))
        blocks: list = []
        for v in vals:
            if int(v) == _MISS:
                break
            blocks.append(int(v))
        if blocks:
            self.hits += 1
        else:
            self.misses += 1
        return len(blocks) * self.block_size, blocks

    def insert_prefix(self, tokens: Sequence[int],
                      block_ids: Sequence[int]) -> int:
        """Register the (complete) blocks of a finished prefill.

        Returns the number of blocks registered.  Maintenance of the
        shortcut directory is asynchronous as always (``pump()`` or the
        mapper thread replays it)."""
        keys = self.chain_keys(tokens)
        n = min(len(keys), len(block_ids))
        if n == 0:
            return 0
        self.index.insert(keys[:n], np.asarray(block_ids[:n], np.uint32))
        return n

    def pump(self):
        self.index.pump()

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "in_sync": self.index.in_sync(),
                "fan_in": self.index.avg_fan_in(),
                "routed_shortcut": self.index.routed_shortcut,
                "routed_traditional": self.index.routed_traditional}

    def close(self):
        self.index.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
