"""Shortcut KV view: the paper's technique applied to the serving layer.

The paged cache reads through *two* indirections (block table, then block
gather).  The shortcut view pre-composes that mapping into a contiguous
per-sequence layout — ``view[l, s, t] = pool[l, table[s, t // bs], t % bs]``
— so a decode step reads it with pure address arithmetic (a dynamic-slice),
zero data-dependent indirections.  This is ``rewiring.compose`` at the KV
granularity.

Exactly like Shortcut-EH (§4.1) — and through the very same runtime
(``runtime/mapper.ShortcutMapper``, DESIGN.md §4): the paged cache stays
authoritative and synchronous; the view is replayed asynchronously from a
FIFO of *update* (append a token row) and *create* (re-linearize a
sequence) requests, is eagerly populated before publication, version-gates
every read (one version per sequence — a sequence is our directory unit),
and a fragmentation statistic (the fan-in analogue) decides routing
(:class:`~repro.runtime.mapper.FragmentationRouting`).

**Sharded mode** (``num_shards > 1``): sequences partition across a
:class:`~repro.runtime.shard_group.MapperGroup` by ``seq_id % N`` — each
shard owns its sequences' versions, FIFO queue, collapse scope, routing
policy and (async) thread, so a prefill burst re-linearizing one shard's
sequences never collapses or gates another shard's decode appends
(DESIGN.md §4, sharded mappers).  The view arrays stay whole-batch
(decode reads them as one tensor); concurrent shard threads mutate
disjoint sequence rows but share the array *objects*, so replay
read-modify-writes serialize on one internal view lock — queueing,
versioning and gating stay fully shard-independent.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kvcache import paged_cache as pc
from repro.runtime.mapper import FragmentationRouting, ShortcutMapper
from repro.runtime.shard_group import MapperGroup


# -- functional core -----------------------------------------------------------

@jax.jit
def compose_seq(cache: pc.PagedKVCache, view_k: jax.Array, view_v: jax.Array,
                seq_id: jax.Array):
    """Create-request replay: linearize one sequence into the view.

    view_k/view_v: (L, max_seqs, S_cap, KV, hd)."""
    table = jnp.maximum(cache.block_tables[seq_id], 0)    # (MB,)
    L = cache.k_pool.shape[0]
    bs = cache.block_size
    MB = table.shape[0]
    kv_shape = cache.k_pool.shape[3:]
    k_lin = cache.k_pool[:, table].reshape((L, MB * bs) + kv_shape)
    v_lin = cache.v_pool[:, table].reshape((L, MB * bs) + kv_shape)
    cap = view_k.shape[2]
    return (view_k.at[:, seq_id, :].set(k_lin[:, :cap]),
            view_v.at[:, seq_id, :].set(v_lin[:, :cap]))


@jax.jit
def append_to_view(view_k: jax.Array, view_v: jax.Array, seq_ids: jax.Array,
                   positions: jax.Array, new_k: jax.Array,
                   new_v: jax.Array):
    """Update-request replay: write one token row per sequence
    (the per-slot ``mmap`` analogue).  new_k/new_v: (L, B, KV, hd)."""
    return (view_k.at[:, seq_ids, positions].set(new_k),
            view_v.at[:, seq_ids, positions].set(new_v))


@jax.jit
def slice_context(view_k: jax.Array, view_v: jax.Array, seq_ids: jax.Array):
    """The shortcut access path: a gather on the *sequence* axis only —
    token positions are pure address arithmetic (contiguous stream).
    Returns (L, B, KV, S, hd) (attention-native layout)."""
    return (view_k[:, seq_ids].transpose(0, 1, 3, 2, 4),
            view_v[:, seq_ids].transpose(0, 1, 3, 2, 4))


# -- host orchestration ----------------------------------------------------------

class ShortcutKVManager:
    """Maintains the shortcut view alongside an authoritative paged cache —
    a thin client of the (sharded) shortcut-maintenance runtime.

    A read routes through the shortcut only when every sequence in the
    batch is in sync *and* the batch fragmentation exceeds
    ``frag_threshold`` (below it, the paged gather streams
    nearly-contiguous blocks anyway, and maintenance would be pure
    overhead — the TLB-thrashing lesson of §3.2 mapped to DMA terms).

    ``num_shards`` partitions sequences across independent mappers
    (``seq_id % num_shards`` router); the default 1 is exactly the
    previous single-mapper behaviour.  A custom ``routing`` policy is
    shared across shards — pass ``None`` for independent per-shard
    :class:`FragmentationRouting` instances.
    """

    def __init__(self, cache: pc.PagedKVCache, seq_capacity: int, *,
                 frag_threshold: float = 0.25, poll_interval: float = 0.025,
                 async_mapper: bool = False, routing=None,
                 num_shards: int = 1):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        L, _, bs, KV, hd = cache.k_pool.shape
        max_seqs = cache.block_tables.shape[0]
        self.cache = cache
        self.view_k = jnp.zeros((L, max_seqs, seq_capacity, KV, hd),
                                cache.k_pool.dtype)
        self.view_v = jnp.zeros_like(self.view_k)
        self._view_lock = threading.Lock()
        self.group = MapperGroup(
            [ShortcutMapper(
                replay_create=lambda snap, reqs, shard=i:
                    self._replay_create(snap, reqs, shard),
                replay_update=lambda snap, reqs, shard=i:
                    self._replay_update(snap, reqs, shard),
                snapshot=lambda: self.cache,
                view_arrays=lambda: (self.view_k, self.view_v),
                routing=routing or FragmentationRouting(float(frag_threshold)),
                poll_interval=poll_interval, async_mapper=async_mapper,
                name=f"kv-mapper-{i}")
             for i in range(num_shards)],
            router=lambda seq_id: int(seq_id) % num_shards)
        self.num_shards = num_shards

    # -- delegated bookkeeping (kept for API compatibility) ------------------

    @property
    def mapper(self) -> ShortcutMapper:
        """The first (with ``num_shards=1``: the only) mapper — the
        pre-sharding single-mapper API surface."""
        return self.group[0]

    @property
    def routed_shortcut(self) -> int:
        return self.group.routed_shortcut

    @property
    def routed_paged(self) -> int:
        return self.group.routed_fallback

    @property
    def frag_threshold(self):
        return self.group[0].threshold

    @frag_threshold.setter
    def frag_threshold(self, value: float) -> None:
        for m in self.group:
            m.threshold = value

    @property
    def stats(self):
        return self.group.stats

    # -- sharding helpers ----------------------------------------------------

    def _by_shard(self, seq_ids: np.ndarray) -> dict:
        """{shard: [seq ids]} preserving batch order within each shard."""
        out: dict = {}
        for s in np.asarray(seq_ids).tolist():
            out.setdefault(self.group.route(int(s)), []).append(int(s))
        return out

    @contextlib.contextmanager
    def _shard_locks(self, shards):
        """Hold the involved shards' runtime locks (ascending order — the
        lock hierarchy that makes multi-shard mutations deadlock-free)."""
        with contextlib.ExitStack() as stack:
            for r in sorted(shards):
                stack.enter_context(self.group[r].lock)
            yield

    # -- main-thread (serving) API -----------------------------------------

    def prefill(self, seq_ids: np.ndarray, k: jax.Array, v: jax.Array):
        """Synchronous paged write + async create request per sequence,
        enqueued on each sequence's owning shard."""
        seq_ids = np.asarray(seq_ids)
        by_shard = self._by_shard(seq_ids)
        with self._shard_locks(by_shard):
            self.cache = pc.write_prefill(
                self.cache, jnp.asarray(seq_ids), k, v)
            versions = {r: self.group[r].record(keys)
                        for r, keys in by_shard.items()}
        for r, keys in by_shard.items():
            self.group[r].submit_create(keys, versions[r],
                                        payload=np.asarray(keys))

    def append(self, seq_ids: np.ndarray, new_k: jax.Array,
               new_v: jax.Array):
        """Synchronous paged append + async view-row update request on
        each sequence's owning shard (payload sliced per shard)."""
        seq_ids = np.asarray(seq_ids)
        shard_of = np.asarray([self.group.route(int(s)) for s in seq_ids])
        by_shard = {r: [int(s) for s in seq_ids[shard_of == r]]
                    for r in sorted(set(shard_of.tolist()))}
        positions = np.asarray(self.cache.seq_lens)[seq_ids]
        with self._shard_locks(by_shard):
            self.cache = pc.append_tokens(
                self.cache, jnp.asarray(seq_ids), new_k, new_v)
            versions = {r: self.group[r].record(keys)
                        for r, keys in by_shard.items()}
        for r, keys in by_shard.items():
            idx = np.nonzero(shard_of == r)[0]
            self.group[r].submit_update(
                keys, versions[r],
                payload=(seq_ids[idx], positions[idx],
                         new_k[:, idx], new_v[:, idx]))

    def release(self, seq_ids: np.ndarray):
        """Synchronous release; the per-sequence views become permanently
        stale until the next prefill recreates them."""
        by_shard = self._by_shard(np.asarray(seq_ids))
        with self._shard_locks(by_shard):
            self.cache = pc.release_seqs(self.cache, jnp.asarray(seq_ids))
            for r, keys in by_shard.items():
                self.group[r].invalidate(keys)

    def in_sync(self, seq_ids: np.ndarray) -> bool:
        return self.group.in_sync(self._by_shard(seq_ids))

    def fragmentation(self, seq_ids: np.ndarray) -> float:
        return float(pc.fragmentation(self.cache, jnp.asarray(seq_ids)))

    def route(self, seq_ids: np.ndarray) -> str:
        """'shortcut' | 'paged' — version gate (across the involved
        shards) + fragmentation cost model."""
        if self.group.gate(self.fragmentation(seq_ids),
                           self._by_shard(seq_ids)):
            return "shortcut"
        return "paged"

    def get_context(self, seq_ids: np.ndarray, route: Optional[str] = None):
        """Materialized (k_ctx, v_ctx) for decode + the route taken."""
        route = route or self.route(seq_ids)
        self.group.count_route(route == "shortcut")
        ids = jnp.asarray(seq_ids)
        if route == "shortcut":
            k, v = slice_context(self.view_k, self.view_v, ids)
        else:
            k, v = pc.gather_context(self.cache, ids)
        return k, v, route

    def seq_lens(self, seq_ids: np.ndarray) -> np.ndarray:
        return np.asarray(self.cache.seq_lens)[seq_ids]

    # -- maintenance (delegated to the runtime) ------------------------------

    def pump(self) -> int:
        return self.group.pump()

    def wait_in_sync(self, seq_ids: np.ndarray, timeout: float = 30.0):
        return self.group.wait_in_sync(self._by_shard(seq_ids), timeout)

    def close(self):
        self.group.close()

    # -- replay callables (the only KV-specific maintenance code) ------------

    def _replay_create(self, cache: pc.PagedKVCache, requests,
                       shard: int = 0) -> None:
        with self._view_lock:
            for r in requests:
                for s in np.asarray(r.payload):
                    self.view_k, self.view_v = compose_seq(
                        cache, self.view_k, self.view_v, jnp.int32(int(s)))
                self.group[shard].stats.slots_remapped += len(r.versions)

    def _replay_update(self, cache: pc.PagedKVCache, requests,
                       shard: int = 0) -> None:
        with self._view_lock:
            for r in requests:
                seq_ids, positions, new_k, new_v = r.payload
                self.view_k, self.view_v = append_to_view(
                    self.view_k, self.view_v, jnp.asarray(seq_ids),
                    jnp.asarray(positions), new_k, new_v)
                self.group[shard].stats.slots_remapped += len(r.versions)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
