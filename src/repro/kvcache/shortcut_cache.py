"""Shortcut KV view: the paper's technique applied to the serving layer.

The paged cache reads through *two* indirections (block table, then block
gather).  The shortcut view pre-composes that mapping into a contiguous
per-sequence layout — ``view[l, s, t] = pool[l, table[s, t // bs], t % bs]``
— so a decode step reads it with pure address arithmetic (a dynamic-slice),
zero data-dependent indirections.  This is ``rewiring.compose`` at the KV
granularity.

Exactly like Shortcut-EH (§4.1): the paged cache stays authoritative and
synchronous; the view is replayed asynchronously from a FIFO of *update*
(append a token row) and *create* (re-linearize a sequence) requests, is
eagerly populated before publication, version-gates every read, and a
fragmentation statistic (the fan-in analogue) decides routing.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kvcache import paged_cache as pc


# -- functional core -----------------------------------------------------------

@jax.jit
def compose_seq(cache: pc.PagedKVCache, view_k: jax.Array, view_v: jax.Array,
                seq_id: jax.Array):
    """Create-request replay: linearize one sequence into the view.

    view_k/view_v: (L, max_seqs, S_cap, KV, hd)."""
    table = jnp.maximum(cache.block_tables[seq_id], 0)    # (MB,)
    L = cache.k_pool.shape[0]
    bs = cache.block_size
    MB = table.shape[0]
    kv_shape = cache.k_pool.shape[3:]
    k_lin = cache.k_pool[:, table].reshape((L, MB * bs) + kv_shape)
    v_lin = cache.v_pool[:, table].reshape((L, MB * bs) + kv_shape)
    cap = view_k.shape[2]
    return (view_k.at[:, seq_id, :].set(k_lin[:, :cap]),
            view_v.at[:, seq_id, :].set(v_lin[:, :cap]))


@jax.jit
def append_to_view(view_k: jax.Array, view_v: jax.Array, seq_ids: jax.Array,
                   positions: jax.Array, new_k: jax.Array,
                   new_v: jax.Array):
    """Update-request replay: write one token row per sequence
    (the per-slot ``mmap`` analogue).  new_k/new_v: (L, B, KV, hd)."""
    return (view_k.at[:, seq_ids, positions].set(new_k),
            view_v.at[:, seq_ids, positions].set(new_v))


@jax.jit
def slice_context(view_k: jax.Array, view_v: jax.Array, seq_ids: jax.Array):
    """The shortcut access path: a gather on the *sequence* axis only —
    token positions are pure address arithmetic (contiguous stream).
    Returns (L, B, KV, S, hd) (attention-native layout)."""
    return (view_k[:, seq_ids].transpose(0, 1, 3, 2, 4),
            view_v[:, seq_ids].transpose(0, 1, 3, 2, 4))


# -- host orchestration ----------------------------------------------------------

@dataclass
class _Request:
    kind: str                      # "append" | "create"
    versions: np.ndarray           # per-seq trad_version at request time
    seq_ids: np.ndarray
    positions: Optional[np.ndarray] = None
    new_k: Optional[jax.Array] = None
    new_v: Optional[jax.Array] = None


class ShortcutKVManager:
    """Maintains the shortcut view alongside an authoritative paged cache.

    Per-sequence version numbers (the paper keeps one per directory; a
    sequence is our directory unit): a read routes through the shortcut only
    when every sequence in the batch is in sync *and* the batch
    fragmentation exceeds ``frag_threshold`` (below it, the paged gather
    streams nearly-contiguous blocks anyway, and maintenance would be pure
    overhead — the TLB-thrashing lesson of §3.2 mapped to DMA terms).
    """

    def __init__(self, cache: pc.PagedKVCache, seq_capacity: int, *,
                 frag_threshold: float = 0.25, poll_interval: float = 0.025,
                 async_mapper: bool = False):
        L, _, bs, KV, hd = cache.k_pool.shape
        max_seqs = cache.block_tables.shape[0]
        self.cache = cache
        self.view_k = jnp.zeros((L, max_seqs, seq_capacity, KV, hd),
                                cache.k_pool.dtype)
        self.view_v = jnp.zeros_like(self.view_k)
        self.frag_threshold = float(frag_threshold)
        self.poll_interval = float(poll_interval)
        self.trad_version = np.zeros((max_seqs,), np.int64)
        self.sc_version = np.full((max_seqs,), -1, np.int64)
        self.routed_shortcut = 0
        self.routed_paged = 0
        self._queue: "queue.SimpleQueue[_Request]" = queue.SimpleQueue()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._mapper: Optional[threading.Thread] = None
        if async_mapper:
            self._mapper = threading.Thread(
                target=self._mapper_loop, daemon=True, name="kv-mapper")
            self._mapper.start()

    # -- main-thread (serving) API -----------------------------------------

    def prefill(self, seq_ids: np.ndarray, k: jax.Array, v: jax.Array):
        """Synchronous paged write + async create request per sequence."""
        with self._lock:
            self.cache = pc.write_prefill(
                self.cache, jnp.asarray(seq_ids), k, v)
            self.trad_version[seq_ids] += 1
            vers = self.trad_version[seq_ids].copy()
        self._queue.put(_Request("create", vers, np.asarray(seq_ids)))

    def append(self, seq_ids: np.ndarray, new_k: jax.Array,
               new_v: jax.Array):
        """Synchronous paged append + async view-row update request."""
        positions = np.asarray(self.cache.seq_lens)[seq_ids]
        with self._lock:
            self.cache = pc.append_tokens(
                self.cache, jnp.asarray(seq_ids), new_k, new_v)
            self.trad_version[seq_ids] += 1
            vers = self.trad_version[seq_ids].copy()
        self._queue.put(_Request(
            "append", vers, np.asarray(seq_ids),
            positions=positions, new_k=new_k, new_v=new_v))

    def release(self, seq_ids: np.ndarray):
        with self._lock:
            self.cache = pc.release_seqs(self.cache, jnp.asarray(seq_ids))
            self.trad_version[seq_ids] += 1
            self.sc_version[seq_ids] = -1

    def in_sync(self, seq_ids: np.ndarray) -> bool:
        return bool((self.sc_version[seq_ids]
                     >= self.trad_version[seq_ids]).all())

    def fragmentation(self, seq_ids: np.ndarray) -> float:
        return float(pc.fragmentation(self.cache, jnp.asarray(seq_ids)))

    def route(self, seq_ids: np.ndarray) -> str:
        """'shortcut' | 'paged' — version gate + fragmentation cost model."""
        if self.in_sync(seq_ids) \
                and self.fragmentation(seq_ids) >= self.frag_threshold:
            return "shortcut"
        return "paged"

    def get_context(self, seq_ids: np.ndarray, route: Optional[str] = None):
        """Materialized (k_ctx, v_ctx) for decode + the route taken."""
        route = route or self.route(seq_ids)
        ids = jnp.asarray(seq_ids)
        if route == "shortcut":
            self.routed_shortcut += 1
            k, v = slice_context(self.view_k, self.view_v, ids)
        else:
            self.routed_paged += 1
            k, v = pc.gather_context(self.cache, ids)
        return k, v, route

    def seq_lens(self, seq_ids: np.ndarray) -> np.ndarray:
        return np.asarray(self.cache.seq_lens)[seq_ids]

    # -- mapper -------------------------------------------------------------

    def pump(self) -> int:
        done = 0
        while True:
            batch = self._drain()
            if not batch:
                return done
            self._process(batch)
            done += len(batch)

    def wait_in_sync(self, seq_ids: np.ndarray, timeout: float = 30.0):
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self.in_sync(seq_ids) and self._queue.empty():
                return True
            if self._mapper is None:
                self.pump()
            else:
                time.sleep(self.poll_interval / 4)
        return self.in_sync(seq_ids)

    def close(self):
        self._stop.set()
        if self._mapper is not None:
            self._mapper.join(timeout=5.0)
            self._mapper = None

    def _drain(self) -> list[_Request]:
        out = []
        while True:
            try:
                out.append(self._queue.get_nowait())
            except queue.Empty:
                return out

    def _mapper_loop(self):
        while not self._stop.is_set():
            batch = self._drain()
            if batch:
                self._process(batch)
            else:
                time.sleep(self.poll_interval)

    def _process(self, batch: list[_Request]):
        with self._lock:
            cache = self.cache
        latest: dict[int, int] = {}
        for r in batch:
            if r.kind == "create":
                for s, ver in zip(r.seq_ids, r.versions):
                    self.view_k, self.view_v = compose_seq(
                        cache, self.view_k, self.view_v, jnp.int32(int(s)))
                    latest[int(s)] = max(latest.get(int(s), -1), int(ver))
            else:
                self.view_k, self.view_v = append_to_view(
                    self.view_k, self.view_v, jnp.asarray(r.seq_ids),
                    jnp.asarray(r.positions), r.new_k, r.new_v)
                for s, ver in zip(r.seq_ids, r.versions):
                    latest[int(s)] = max(latest.get(int(s), -1), int(ver))
        # eager population before publishing versions (§3.1)
        self.view_k.block_until_ready()
        self.view_v.block_until_ready()
        for s, ver in latest.items():
            self.sc_version[s] = max(self.sc_version[s], ver)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
