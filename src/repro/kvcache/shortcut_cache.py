"""Shortcut KV view: the paper's technique applied to the serving layer.

The paged cache reads through *two* indirections (block table, then block
gather).  The shortcut view pre-composes that mapping into a contiguous
per-sequence layout — ``view[l, s, t] = pool[l, table[s, t // bs], t % bs]``
— so a decode step reads it with pure address arithmetic (a dynamic-slice),
zero data-dependent indirections.  This is ``rewiring.compose`` at the KV
granularity.

Exactly like Shortcut-EH (§4.1) — and through the very same runtime
(``runtime/mapper.ShortcutMapper``, DESIGN.md §4): the paged cache stays
authoritative and synchronous; the view is replayed asynchronously from a
FIFO of *update* (append a token row) and *create* (re-linearize a
sequence) requests, is eagerly populated before publication, version-gates
every read (one version per sequence — a sequence is our directory unit),
and a fragmentation statistic (the fan-in analogue) decides routing
(:class:`~repro.runtime.mapper.FragmentationRouting`).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kvcache import paged_cache as pc
from repro.runtime.mapper import FragmentationRouting, ShortcutMapper


# -- functional core -----------------------------------------------------------

@jax.jit
def compose_seq(cache: pc.PagedKVCache, view_k: jax.Array, view_v: jax.Array,
                seq_id: jax.Array):
    """Create-request replay: linearize one sequence into the view.

    view_k/view_v: (L, max_seqs, S_cap, KV, hd)."""
    table = jnp.maximum(cache.block_tables[seq_id], 0)    # (MB,)
    L = cache.k_pool.shape[0]
    bs = cache.block_size
    MB = table.shape[0]
    kv_shape = cache.k_pool.shape[3:]
    k_lin = cache.k_pool[:, table].reshape((L, MB * bs) + kv_shape)
    v_lin = cache.v_pool[:, table].reshape((L, MB * bs) + kv_shape)
    cap = view_k.shape[2]
    return (view_k.at[:, seq_id, :].set(k_lin[:, :cap]),
            view_v.at[:, seq_id, :].set(v_lin[:, :cap]))


@jax.jit
def append_to_view(view_k: jax.Array, view_v: jax.Array, seq_ids: jax.Array,
                   positions: jax.Array, new_k: jax.Array,
                   new_v: jax.Array):
    """Update-request replay: write one token row per sequence
    (the per-slot ``mmap`` analogue).  new_k/new_v: (L, B, KV, hd)."""
    return (view_k.at[:, seq_ids, positions].set(new_k),
            view_v.at[:, seq_ids, positions].set(new_v))


@jax.jit
def slice_context(view_k: jax.Array, view_v: jax.Array, seq_ids: jax.Array):
    """The shortcut access path: a gather on the *sequence* axis only —
    token positions are pure address arithmetic (contiguous stream).
    Returns (L, B, KV, S, hd) (attention-native layout)."""
    return (view_k[:, seq_ids].transpose(0, 1, 3, 2, 4),
            view_v[:, seq_ids].transpose(0, 1, 3, 2, 4))


# -- host orchestration ----------------------------------------------------------

class ShortcutKVManager:
    """Maintains the shortcut view alongside an authoritative paged cache —
    a thin client of the shortcut-maintenance runtime.

    A read routes through the shortcut only when every sequence in the
    batch is in sync *and* the batch fragmentation exceeds
    ``frag_threshold`` (below it, the paged gather streams
    nearly-contiguous blocks anyway, and maintenance would be pure
    overhead — the TLB-thrashing lesson of §3.2 mapped to DMA terms).
    """

    def __init__(self, cache: pc.PagedKVCache, seq_capacity: int, *,
                 frag_threshold: float = 0.25, poll_interval: float = 0.025,
                 async_mapper: bool = False, routing=None):
        L, _, bs, KV, hd = cache.k_pool.shape
        max_seqs = cache.block_tables.shape[0]
        self.cache = cache
        self.view_k = jnp.zeros((L, max_seqs, seq_capacity, KV, hd),
                                cache.k_pool.dtype)
        self.view_v = jnp.zeros_like(self.view_k)
        self.mapper = ShortcutMapper(
            replay_create=self._replay_create,
            replay_update=self._replay_update,
            snapshot=lambda: self.cache,
            view_arrays=lambda: (self.view_k, self.view_v),
            routing=routing or FragmentationRouting(float(frag_threshold)),
            poll_interval=poll_interval, async_mapper=async_mapper,
            name="kv-mapper")

    # -- delegated bookkeeping (kept for API compatibility) ------------------

    @property
    def routed_shortcut(self) -> int:
        return self.mapper.routed_shortcut

    @property
    def routed_paged(self) -> int:
        return self.mapper.routed_fallback

    @property
    def frag_threshold(self):
        return self.mapper.threshold

    @frag_threshold.setter
    def frag_threshold(self, value: float) -> None:
        self.mapper.threshold = value

    @property
    def stats(self):
        return self.mapper.stats

    # -- main-thread (serving) API -----------------------------------------

    def prefill(self, seq_ids: np.ndarray, k: jax.Array, v: jax.Array):
        """Synchronous paged write + async create request per sequence."""
        keys = [int(s) for s in np.asarray(seq_ids)]
        with self.mapper.lock:
            self.cache = pc.write_prefill(
                self.cache, jnp.asarray(seq_ids), k, v)
            versions = self.mapper.record(keys)
        self.mapper.submit_create(keys, versions,
                                  payload=np.asarray(seq_ids))

    def append(self, seq_ids: np.ndarray, new_k: jax.Array,
               new_v: jax.Array):
        """Synchronous paged append + async view-row update request."""
        seq_ids = np.asarray(seq_ids)
        keys = [int(s) for s in seq_ids]
        positions = np.asarray(self.cache.seq_lens)[seq_ids]
        with self.mapper.lock:
            self.cache = pc.append_tokens(
                self.cache, jnp.asarray(seq_ids), new_k, new_v)
            versions = self.mapper.record(keys)
        self.mapper.submit_update(
            keys, versions, payload=(seq_ids, positions, new_k, new_v))

    def release(self, seq_ids: np.ndarray):
        """Synchronous release; the per-sequence views become permanently
        stale until the next prefill recreates them."""
        with self.mapper.lock:
            self.cache = pc.release_seqs(self.cache, jnp.asarray(seq_ids))
            self.mapper.invalidate([int(s) for s in np.asarray(seq_ids)])

    def in_sync(self, seq_ids: np.ndarray) -> bool:
        return self.mapper.in_sync(int(s) for s in np.asarray(seq_ids))

    def fragmentation(self, seq_ids: np.ndarray) -> float:
        return float(pc.fragmentation(self.cache, jnp.asarray(seq_ids)))

    def route(self, seq_ids: np.ndarray) -> str:
        """'shortcut' | 'paged' — version gate + fragmentation cost model."""
        if self.mapper.gate(self.fragmentation(seq_ids),
                            (int(s) for s in np.asarray(seq_ids))):
            return "shortcut"
        return "paged"

    def get_context(self, seq_ids: np.ndarray, route: Optional[str] = None):
        """Materialized (k_ctx, v_ctx) for decode + the route taken."""
        route = route or self.route(seq_ids)
        self.mapper.count_route(route == "shortcut")
        ids = jnp.asarray(seq_ids)
        if route == "shortcut":
            k, v = slice_context(self.view_k, self.view_v, ids)
        else:
            k, v = pc.gather_context(self.cache, ids)
        return k, v, route

    def seq_lens(self, seq_ids: np.ndarray) -> np.ndarray:
        return np.asarray(self.cache.seq_lens)[seq_ids]

    # -- maintenance (delegated to the runtime) ------------------------------

    def pump(self) -> int:
        return self.mapper.pump()

    def wait_in_sync(self, seq_ids: np.ndarray, timeout: float = 30.0):
        return self.mapper.wait_in_sync(
            [int(s) for s in np.asarray(seq_ids)], timeout)

    def close(self):
        self.mapper.close()

    # -- replay callables (the only KV-specific maintenance code) ------------

    def _replay_create(self, cache: pc.PagedKVCache, requests) -> None:
        for r in requests:
            for s in np.asarray(r.payload):
                self.view_k, self.view_v = compose_seq(
                    cache, self.view_k, self.view_v, jnp.int32(int(s)))
            self.mapper.stats.slots_remapped += len(r.versions)

    def _replay_update(self, cache: pc.PagedKVCache, requests) -> None:
        for r in requests:
            seq_ids, positions, new_k, new_v = r.payload
            self.view_k, self.view_v = append_to_view(
                self.view_k, self.view_v, jnp.asarray(seq_ids),
                jnp.asarray(positions), new_k, new_v)
            self.mapper.stats.slots_remapped += len(r.versions)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
