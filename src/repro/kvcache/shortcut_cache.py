"""Shortcut KV view: the paper's technique applied to the serving layer.

The paged cache reads through *two* indirections (block table, then block
gather).  The shortcut view pre-composes that mapping into a contiguous
per-sequence layout — ``view[l, s, t] = pool[l, table[s, t // bs], t % bs]``
— so a decode step reads it with pure address arithmetic (a dynamic-slice),
zero data-dependent indirections.  This is ``rewiring.compose`` at the KV
granularity.

Exactly like Shortcut-EH (§4.1) — and through the very same runtime
(``runtime/mapper.ShortcutMapper``, DESIGN.md §4): the paged cache stays
authoritative and synchronous; the view is replayed asynchronously from a
FIFO of *update* (append a token row) and *create* (re-linearize a
sequence) requests, is eagerly populated before publication, version-gates
every read (one version per sequence — a sequence is our directory unit),
and a fragmentation statistic (the fan-in analogue) decides routing
(:class:`~repro.runtime.mapper.FragmentationRouting`).

**Sharded mode** (``num_shards > 1``, DESIGN.md §4.2): sequences partition
across a :class:`~repro.runtime.shard_group.MapperGroup` by
``seq_id % N``, and — unlike the first sharded iteration, which kept one
whole-batch view pair behind a global view lock — the view state is
**per shard** too: shard ``s`` owns the rows of its sequences
(shard-local row ``seq_id // N``).  The PRIMARY storage is one stacked
``(N, L, seqs_per_shard, S_cap, KV, hd)`` k/v pair held by a
:class:`~repro.runtime.operand_cache.StackedOperandCache` (family
"kv_view", DESIGN.md §4.4); the
:class:`~repro.runtime.shard_group.ShardViewRegistry` is a per-shard
facade of it.  A replay thread reads its shard's memoized slice of the
stack, chains the functional updates, and publishes ONE slice write back
into the stack — at the mapper's ``next_view_epoch``, *before*
``sc_version`` moves — so the replay path acquires no cross-shard lock
(there is no view lock at all), and a reader's snapshot is drawn from
one atomically-swapped stacked tuple (it can never pair a ``view_k``
from one publication with the ``view_v`` of another).  Reads
(``get_context`` over any batch) take the stack by handle after a pure
epoch check — zero refresh work on the read path in steady state — and
gather rows with one fused two-axis gather in input order.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kvcache import paged_cache as pc
from repro.runtime.mapper import FragmentationRouting, ShortcutMapper
from repro.runtime.operand_cache import StackedOperandCache
from repro.runtime.shard_group import MapperGroup, ShardViewRegistry


# -- functional core -----------------------------------------------------------

@jax.jit
def compose_seq(cache: pc.PagedKVCache, view_k: jax.Array, view_v: jax.Array,
                seq_id: jax.Array, row: jax.Array):
    """Create-request replay: linearize one sequence into its shard's view.

    view_k/view_v: (L, rows_per_shard, S_cap, KV, hd); ``seq_id`` indexes
    the authoritative cache, ``row`` the shard-local view row owning it
    (``seq_id // num_shards``; with one shard, ``row == seq_id``).

    Positions at or past the sequence's current length are written as
    **zeros**, not whatever the pool holds there.  Unset block-table
    entries read (via the ``maximum(…, 0)`` guard) physical block 0, and
    the tail of the last partial block carries stale rows from whatever
    sequence last recycled those blocks — both are functions of *when*
    the replay ran, so leaving them in the view made two managers
    replaying the same schedule at different times publish bit-different
    rows past ``seq_len`` (the ``test_randomized_schedule_parity``
    flake).  Masking pins every position ≥ ``seq_len`` to zero, making
    the composed row a pure function of the sequence's content."""
    table = jnp.maximum(cache.block_tables[seq_id], 0)    # (MB,)
    L = cache.k_pool.shape[0]
    bs = cache.block_size
    MB = table.shape[0]
    kv_shape = cache.k_pool.shape[3:]
    k_lin = cache.k_pool[:, table].reshape((L, MB * bs) + kv_shape)
    v_lin = cache.v_pool[:, table].reshape((L, MB * bs) + kv_shape)
    cap = view_k.shape[2]
    live = (jnp.arange(cap) < cache.seq_lens[seq_id])[:, None, None]
    k_row = jnp.where(live, k_lin[:, :cap], 0)
    v_row = jnp.where(live, v_lin[:, :cap], 0)
    return (view_k.at[:, row, :].set(k_row),
            view_v.at[:, row, :].set(v_row))


@jax.jit
def append_to_view(view_k: jax.Array, view_v: jax.Array, rows: jax.Array,
                   positions: jax.Array, new_k: jax.Array,
                   new_v: jax.Array):
    """Update-request replay: write one token row per sequence
    (the per-slot ``mmap`` analogue) at the given shard-local rows.
    new_k/new_v: (L, B, KV, hd)."""
    return (view_k.at[:, rows, positions].set(new_k),
            view_v.at[:, rows, positions].set(new_v))


@jax.jit
def slice_context(view_k: jax.Array, view_v: jax.Array, rows: jax.Array):
    """The shortcut access path: a gather on the *row* axis only —
    token positions are pure address arithmetic (contiguous stream).
    Returns (L, B, KV, S, hd) (attention-native layout)."""
    return (view_k[:, rows].transpose(0, 1, 3, 2, 4),
            view_v[:, rows].transpose(0, 1, 3, 2, 4))


@jax.jit
def stacked_context(stack_k: jax.Array, stack_v: jax.Array,
                    sid: jax.Array, rows: jax.Array):
    """:func:`slice_context` lifted to the stacked primary
    ``(N, L, rows, S_cap, KV, hd)``: one fused two-axis gather in input
    order, serving single- and cross-shard batches identically.
    Returns (L, B, KV, S, hd)."""
    k = stack_k[sid, :, rows]               # (B, L, S_cap, KV, hd)
    v = stack_v[sid, :, rows]
    return (jnp.transpose(k, (1, 0, 3, 2, 4)),
            jnp.transpose(v, (1, 0, 3, 2, 4)))


# -- host orchestration ----------------------------------------------------------

class ShortcutKVManager:
    """Maintains the shortcut view alongside an authoritative paged cache —
    a thin client of the (sharded) shortcut-maintenance runtime.

    A read routes through the shortcut only when every sequence in the
    batch is in sync *and* the batch fragmentation exceeds
    ``frag_threshold`` (below it, the paged gather streams
    nearly-contiguous blocks anyway, and maintenance would be pure
    overhead — the TLB-thrashing lesson of §3.2 mapped to DMA terms).

    ``num_shards`` partitions sequences across independent mappers AND
    independent view tensors (``seq_id % num_shards`` router, shard-local
    view row ``seq_id // num_shards``); the default 1 is exactly the
    previous single-mapper behaviour.  A custom ``routing`` policy is
    shared across shards — pass ``None`` for independent per-shard
    :class:`FragmentationRouting` instances.
    """

    def __init__(self, cache: pc.PagedKVCache, seq_capacity: int, *,
                 frag_threshold: float = 0.25, poll_interval: float = 0.025,
                 async_mapper: bool = False, routing=None,
                 num_shards: int = 1):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        L, _, bs, KV, hd = cache.k_pool.shape
        max_seqs = cache.block_tables.shape[0]
        self.cache = cache
        self.num_shards = num_shards
        self.seqs_per_shard = -(-max_seqs // num_shards)
        # The stacked (N, L, rows, S_cap, KV, hd) view pair is the
        # PRIMARY storage (family "kv_view", DESIGN.md §4.4): replay
        # threads publish their shard's slice straight into it at
        # publish time, readers take the whole stack (cross-shard
        # get_context) or a memoized slice of it (per-shard snapshot /
        # replay read-modify-write) — no per-shard duplicates exist.
        self.operands = StackedOperandCache(num_shards)
        self.views = ShardViewRegistry(num_shards, cache=self.operands,
                                       family="kv_view")
        zk = jnp.zeros((L, self.seqs_per_shard, seq_capacity, KV, hd),
                       cache.k_pool.dtype)
        zv = jnp.zeros_like(zk)
        # seed every shard published-at-zero: the all-zero view is a
        # valid (empty) publication, so first replays take the update
        # path exactly as before
        self.operands.seed("kv_view", [(zk, zv)] * num_shards)
        self._view_shape = tuple(zk.shape)
        self.group = MapperGroup(
            [ShortcutMapper(
                replay_create=lambda snap, reqs, shard=i:
                    self._replay_create(snap, reqs, shard),
                replay_update=lambda snap, reqs, shard=i:
                    self._replay_update(snap, reqs, shard),
                snapshot=lambda: self.cache,
                view_arrays=lambda shard=i: self.views.arrays(shard),
                routing=routing or FragmentationRouting(float(frag_threshold)),
                poll_interval=poll_interval, async_mapper=async_mapper,
                name=f"kv-mapper-{i}")
             for i in range(num_shards)],
            router=lambda seq_id: int(seq_id) % num_shards,
            views=self.views)

    # -- delegated bookkeeping (kept for API compatibility) ------------------

    @property
    def mapper(self) -> ShortcutMapper:
        """The first (with ``num_shards=1``: the only) mapper — the
        pre-sharding single-mapper API surface."""
        return self.group[0]

    @property
    def routed_shortcut(self) -> int:
        return self.group.routed_shortcut

    @property
    def routed_paged(self) -> int:
        return self.group.routed_fallback

    @property
    def frag_threshold(self):
        return self.group[0].threshold

    @frag_threshold.setter
    def frag_threshold(self, value: float) -> None:
        for m in self.group:
            m.threshold = value

    @property
    def stats(self):
        return self.group.stats

    # -- sharding helpers ----------------------------------------------------

    def _by_shard(self, seq_ids: np.ndarray) -> dict:
        """{shard: [seq ids]} preserving batch order within each shard."""
        out: dict = {}
        for s in np.asarray(seq_ids).tolist():
            out.setdefault(self.group.route(int(s)), []).append(int(s))
        return out

    @contextlib.contextmanager
    def _shard_locks(self, shards):
        """Hold the involved shards' runtime locks (ascending order — the
        lock hierarchy that makes multi-shard mutations deadlock-free).
        Main-thread (authoritative) mutations only; the replay path never
        enters here."""
        with contextlib.ExitStack() as stack:
            for r in sorted(shards):
                stack.enter_context(self.group[r].lock)
            yield

    # -- main-thread (serving) API -----------------------------------------

    def prefill(self, seq_ids: np.ndarray, k: jax.Array, v: jax.Array):
        """Synchronous paged write + async create request per sequence,
        enqueued on each sequence's owning shard."""
        seq_ids = np.asarray(seq_ids)
        by_shard = self._by_shard(seq_ids)
        with self._shard_locks(by_shard):
            self.cache = pc.write_prefill(
                self.cache, jnp.asarray(seq_ids), k, v)
            # submit under the same locks that assigned the versions:
            # requests then enter each shard's FIFO in version order, so
            # a replayed later version can never publish in_sync while an
            # earlier-version request is still unsubmitted
            for r, keys in by_shard.items():
                self.group[r].submit_create(keys, self.group[r].record(keys),
                                            payload=np.asarray(keys))

    def append(self, seq_ids: np.ndarray, new_k: jax.Array,
               new_v: jax.Array):
        """Synchronous paged append + async view-row update request on
        each sequence's owning shard (payload sliced per shard)."""
        seq_ids = np.asarray(seq_ids)
        # partition through the group router — the one key->shard map
        # every operation shares
        shard_of = np.asarray([self.group.route(int(s)) for s in seq_ids])
        by_shard = {r: [int(s) for s in seq_ids[shard_of == r]]
                    for r in sorted(set(shard_of.tolist()))}
        with self._shard_locks(by_shard):
            # positions must be read under the shard locks, atomically
            # with the authoritative append: a racing append to the same
            # sequence would otherwise hand two update requests the same
            # (stale) position and the view would lose a token row
            positions = np.asarray(self.cache.seq_lens)[seq_ids]
            self.cache = pc.append_tokens(
                self.cache, jnp.asarray(seq_ids), new_k, new_v)
            # submit under the locks (see prefill): version order ==
            # FIFO order per shard
            for r, keys in by_shard.items():
                idx = np.nonzero(shard_of == r)[0]
                self.group[r].submit_update(
                    keys, self.group[r].record(keys),
                    payload=(seq_ids[idx], positions[idx],
                             new_k[:, idx], new_v[:, idx]))

    def release(self, seq_ids: np.ndarray):
        """Synchronous release; the per-sequence views become permanently
        stale until the next prefill recreates them."""
        by_shard = self._by_shard(np.asarray(seq_ids))
        with self._shard_locks(by_shard):
            self.cache = pc.release_seqs(self.cache, jnp.asarray(seq_ids))
            for r, keys in by_shard.items():
                self.group[r].invalidate(keys)

    def in_sync(self, seq_ids: np.ndarray) -> bool:
        return self.group.in_sync(self._by_shard(seq_ids))

    def fragmentation(self, seq_ids: np.ndarray) -> float:
        return float(pc.fragmentation(self.cache, jnp.asarray(seq_ids)))

    def route(self, seq_ids: np.ndarray) -> str:
        """'shortcut' | 'paged' — version gate (across the involved
        shards) + fragmentation cost model."""
        if self.group.gate(self.fragmentation(seq_ids),
                           self._by_shard(seq_ids)):
            return "shortcut"
        return "paged"

    def get_context(self, seq_ids: np.ndarray, route: Optional[str] = None):
        """Materialized (k_ctx, v_ctx) for decode + the route taken.

        The shortcut path reads per-shard view tensors: a batch confined
        to one shard is a single row-gather on that shard's arrays; a
        batch spanning shards gathers from the device-resident stacked
        pair held by the operand cache (one fused two-axis gather in
        input order — no argsort, no per-call stacking; the cache
        refreshes only slices whose shard published since the last
        batch)."""
        seq_ids = np.asarray(seq_ids)
        if seq_ids.size == 0:
            # empty batch: no fragmentation statistic, no gather, no
            # route counters, no operand-cache traffic — nothing may
            # touch the views (shapes come from the recorded extent)
            L, _, S, KV, hd = self._view_shape
            empty = jnp.zeros((L, 0, KV, S, hd), self.cache.k_pool.dtype)
            return empty, empty, route or "paged"
        route = route or self.route(seq_ids)
        # batch-level decision -> group-level counter (a multi-shard
        # batch must not skew shard 0's per-shard stats)
        self.group.count_route(route == "shortcut")
        if route == "shortcut":
            k, v = self._shortcut_context(seq_ids)
        else:
            k, v = pc.gather_context(self.cache, jnp.asarray(seq_ids))
        return k, v, route

    def _shortcut_context(self, seq_ids: np.ndarray):
        """View read in input order, straight off the stacked primary.

        One fused two-axis gather ``stack[sid, :, row]`` serves single-
        and multi-shard batches alike — input order falls out of the
        index arrays, and the stack needs no per-call refresh: replays
        published their slices into it BEFORE bumping ``view_epoch`` and
        ``sc_version``, so ``get`` here is an epoch check plus a handle
        return (a stack older than what the route gate certified cannot
        be served; a publish racing this read only makes the stack
        newer).  Epochs are read before the handle, per the protocol."""
        epochs = [m.view_epoch for m in self.group]
        stack_k, stack_v = self.operands.get("kv_view", epochs)
        sid = seq_ids % self.num_shards
        rows = seq_ids // self.num_shards
        return stacked_context(stack_k, stack_v, jnp.asarray(sid),
                               jnp.asarray(rows))

    def seq_lens(self, seq_ids: np.ndarray) -> np.ndarray:
        return np.asarray(self.cache.seq_lens)[seq_ids]

    # -- maintenance (delegated to the runtime) ------------------------------

    def pump(self) -> int:
        return self.group.pump()

    def wait_in_sync(self, seq_ids: np.ndarray, timeout: float = 30.0):
        return self.group.wait_in_sync(self._by_shard(seq_ids), timeout)

    def close(self):
        self.group.close()

    # -- replay callables (the only KV-specific maintenance code) ------------
    #
    # Lock-free: each replay runs on its shard's single mapper (thread or
    # pump caller), reads its shard's memoized slice of the stacked
    # primary, chains the functional updates, and publishes ONE slice
    # write back into the stack — at the mapper's next_view_epoch,
    # before sc_version moves (zero-copy publish, DESIGN.md §4.4).  No
    # other shard's slice is read or written — concurrent shard replays
    # never serialize on anything but the cache's brief patch lock.

    def _replay_create(self, cache: pc.PagedKVCache, requests,
                       shard: int = 0) -> None:
        vk, vv = self.views.snapshot(shard)
        for r in requests:
            for s in np.asarray(r.payload):
                vk, vv = compose_seq(
                    cache, vk, vv, jnp.int32(int(s)),
                    jnp.int32(int(s) // self.num_shards))
            self.group[shard].stats.slots_remapped += len(r.versions)
        self.views.publish(shard, (vk, vv),
                           epoch=self.group[shard].next_view_epoch)

    def _replay_update(self, cache: pc.PagedKVCache, requests,
                       shard: int = 0) -> None:
        vk, vv = self.views.snapshot(shard)
        for r in requests:
            seq_ids, positions, new_k, new_v = r.payload
            rows = np.asarray(seq_ids) // self.num_shards
            vk, vv = append_to_view(
                vk, vv, jnp.asarray(rows),
                jnp.asarray(positions), new_k, new_v)
            self.group[shard].stats.slots_remapped += len(r.versions)
        self.views.publish(shard, (vk, vv),
                           epoch=self.group[shard].next_view_epoch)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
