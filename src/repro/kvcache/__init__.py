from repro.kvcache.paged_cache import (PagedKVCache, append_tokens,  # noqa
                                       cache_create, gather_context,
                                       fragmentation, release_seqs,
                                       write_prefill)
from repro.kvcache.shortcut_cache import ShortcutKVManager  # noqa: F401
