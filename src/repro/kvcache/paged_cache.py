"""Paged KV cache: the serving-layer "traditional directory".

Mapping onto the paper (DESIGN.md §3):

  paper                         here
  -----                         ----
  physical page pool            (L, num_blocks, block, KV, hd) HBM pools
  traditional inner node        per-sequence block table (logical->physical)
  pointer dereference           block-table gather in :func:`gather_context`
  pool free-offset queue        ring-buffer allocator (same as rewiring.py)

All ops are functional and jittable; the async shortcut view lives in
``shortcut_cache.py``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class PagedKVCache(NamedTuple):
    k_pool: jax.Array        # (L, num_blocks, block_size, KV, hd)
    v_pool: jax.Array        # (L, num_blocks, block_size, KV, hd)
    block_tables: jax.Array  # (max_seqs, max_blocks_per_seq) int32, -1 unset
    seq_lens: jax.Array      # (max_seqs,) int32 tokens stored
    free_ring: jax.Array     # (num_blocks,) int32 free physical block ids
    free_head: jax.Array     # () int32
    free_count: jax.Array    # () int32

    @property
    def num_layers(self) -> int:
        return self.k_pool.shape[0]

    @property
    def num_blocks(self) -> int:
        return self.k_pool.shape[1]

    @property
    def block_size(self) -> int:
        return self.k_pool.shape[2]

    @property
    def max_blocks_per_seq(self) -> int:
        return self.block_tables.shape[1]


def cache_create(num_layers: int, num_blocks: int, block_size: int,
                 kv_heads: int, head_dim: int, max_seqs: int,
                 max_blocks_per_seq: int, dtype=jnp.bfloat16) -> PagedKVCache:
    return PagedKVCache(
        k_pool=jnp.zeros((num_layers, num_blocks, block_size, kv_heads,
                          head_dim), dtype),
        v_pool=jnp.zeros((num_layers, num_blocks, block_size, kv_heads,
                          head_dim), dtype),
        block_tables=jnp.full((max_seqs, max_blocks_per_seq), -1, jnp.int32),
        seq_lens=jnp.zeros((max_seqs,), jnp.int32),
        free_ring=jnp.arange(num_blocks, dtype=jnp.int32),
        free_head=jnp.zeros((), jnp.int32),
        free_count=jnp.full((), num_blocks, jnp.int32),
    )


def _alloc_blocks(cache: PagedKVCache, need: jax.Array):
    """Vectorized pop of blocks for sequences with need[i]=True.

    Returns (cache, block_ids (B,)) with -1 where not needed/exhausted."""
    B = need.shape[0]
    rank = jnp.cumsum(need.astype(jnp.int32)) - need.astype(jnp.int32)
    total = need.sum()
    ring_pos = (cache.free_head + rank) % cache.num_blocks
    ids = jnp.where(need & (rank < cache.free_count),
                    cache.free_ring[ring_pos], -1)
    granted = (ids >= 0).sum()
    cache = cache._replace(
        free_head=(cache.free_head + granted) % cache.num_blocks,
        free_count=cache.free_count - granted)
    return cache, ids


@jax.jit
def append_tokens(cache: PagedKVCache, seq_ids: jax.Array,
                  new_k: jax.Array, new_v: jax.Array) -> PagedKVCache:
    """Append one token per active sequence (the synchronous, authoritative
    update — the paper's traditional-directory modification).

    seq_ids: (B,) int32; new_k/new_v: (L, B, KV, hd).
    """
    bs = cache.block_size
    pos = cache.seq_lens[seq_ids]                   # (B,)
    block_idx = pos // bs
    slot = pos % bs
    need_new = slot == 0
    cache, fresh = _alloc_blocks(cache, need_new)
    tables = cache.block_tables.at[seq_ids, block_idx].set(
        jnp.where(need_new, fresh, cache.block_tables[seq_ids, block_idx]))
    phys = tables[seq_ids, block_idx]               # (B,)
    k_pool = cache.k_pool.at[:, phys, slot].set(new_k)
    v_pool = cache.v_pool.at[:, phys, slot].set(new_v)
    return cache._replace(
        k_pool=k_pool, v_pool=v_pool, block_tables=tables,
        seq_lens=cache.seq_lens.at[seq_ids].add(1))


@jax.jit
def write_prefill(cache: PagedKVCache, seq_ids: jax.Array,
                  k: jax.Array, v: jax.Array) -> PagedKVCache:
    """Bulk-write a prefill: k/v (L, B, S, KV, hd), S divisible by block."""
    L, B, S = k.shape[:3]
    bs = cache.block_size
    nb = S // bs
    need = jnp.ones((B * nb,), jnp.bool_)
    cache, fresh = _alloc_blocks(cache, need)
    fresh = fresh.reshape(B, nb)
    tables = cache.block_tables.at[seq_ids[:, None],
                                   jnp.arange(nb)[None]].set(fresh)
    kb = k.reshape(L, B, nb, bs, k.shape[3], k.shape[4])
    vb = v.reshape(L, B, nb, bs, v.shape[3], v.shape[4])
    k_pool = cache.k_pool.at[:, fresh].set(kb)
    v_pool = cache.v_pool.at[:, fresh].set(vb)
    return cache._replace(
        k_pool=k_pool, v_pool=v_pool, block_tables=tables,
        seq_lens=cache.seq_lens.at[seq_ids].set(S))


@jax.jit
def release_seqs(cache: PagedKVCache, seq_ids: jax.Array) -> PagedKVCache:
    """Return all blocks of the given sequences to the free ring."""
    rows = cache.block_tables[seq_ids]              # (B, MB)
    live = rows >= 0
    flat = rows.reshape(-1)
    flive = live.reshape(-1)
    rank = jnp.cumsum(flive.astype(jnp.int32)) - flive.astype(jnp.int32)
    tail = (cache.free_head + cache.free_count + rank) % cache.num_blocks
    ring = cache.free_ring.at[jnp.where(flive, tail, cache.num_blocks)].set(
        flat, mode="drop")
    return cache._replace(
        free_ring=ring,
        free_count=cache.free_count + flive.sum(),
        block_tables=cache.block_tables.at[seq_ids].set(-1),
        seq_lens=cache.seq_lens.at[seq_ids].set(0))


@jax.jit
def gather_context(cache: PagedKVCache, seq_ids: jax.Array):
    """The *traditional* access path: two dependent indirections —
    block-table load, then physical-block gather.

    Returns (k_ctx, v_ctx): (L, B, KV, max_blocks*block, hd)
    (attention-native layout)."""
    tables = cache.block_tables[seq_ids]            # (B, MB) indirection 1
    safe = jnp.maximum(tables, 0)
    k = cache.k_pool[:, safe]                       # indirection 2 (gather)
    v = cache.v_pool[:, safe]
    L, B, MB, bs, KV, hd = k.shape
    return (k.transpose(0, 1, 4, 2, 3, 5).reshape(L, B, KV, MB * bs, hd),
            v.transpose(0, 1, 4, 2, 3, 5).reshape(L, B, KV, MB * bs, hd))


def fragmentation(cache: PagedKVCache, seq_ids: jax.Array) -> jax.Array:
    """Routing statistic (the fan-in analogue, §3.2): fraction of
    logically-adjacent block pairs that are physically non-adjacent."""
    tables = cache.block_tables[seq_ids]
    a, b = tables[:, :-1], tables[:, 1:]
    live = (a >= 0) & (b >= 0)
    non_adj = live & (b != a + 1)
    return non_adj.sum().astype(jnp.float32) \
        / jnp.maximum(live.sum(), 1).astype(jnp.float32)
