"""Fault-tolerance runtime pieces: heartbeat watchdog, straggler detection,
and the restartable step-loop driver.

On a real multi-pod deployment every host runs this agent; here the same
code paths are exercised single-process (tests inject failures).

  * :class:`Heartbeat` — worker-side: stamp a monotonic beat per step.
  * :class:`Watchdog` — controller-side thread: if any worker's beat goes
    stale past ``deadline_s``, fire the registered callback (the launcher's
    callback checkpoints-and-reconfigures: shrink the mesh, restore the
    latest step, continue — elastic scaling down; scale-up is the same path
    on join).
  * :class:`StragglerMonitor` — per-step duration EWMA; a step slower than
    ``threshold x`` median flags the host so the scheduler can re-slice data
    skew or evict the host.  (On TPU pods real stragglers surface as slow
    collectives; detection still lives host-side on step timing.)
  * :func:`run_restartable` — the crash-loop driver: run -> on failure
    restore latest checkpoint -> resume, up to ``max_restarts``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional


class Heartbeat:
    def __init__(self, worker_id: int = 0):
        self.worker_id = worker_id
        self._last = time.monotonic()
        self._step = -1
        self._lock = threading.Lock()

    def beat(self, step: int) -> None:
        with self._lock:
            self._last = time.monotonic()
            self._step = step

    def age(self) -> float:
        with self._lock:
            return time.monotonic() - self._last

    @property
    def step(self) -> int:
        with self._lock:
            return self._step


class Watchdog:
    """Fires ``on_dead(worker_ids)`` when beats go stale."""

    def __init__(self, heartbeats: list, deadline_s: float,
                 on_dead: Callable[[list], None],
                 poll_s: float = 0.05):
        self.heartbeats = heartbeats
        self.deadline_s = deadline_s
        self.on_dead = on_dead
        self.poll_s = poll_s
        self._stop = threading.Event()
        self._fired: set = set()
        self._thread: Optional[threading.Thread] = None

    def start(self):
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="watchdog")
        self._thread.start()
        return self

    def _loop(self):
        while not self._stop.is_set():
            dead = [hb.worker_id for hb in self.heartbeats
                    if hb.age() > self.deadline_s
                    and hb.worker_id not in self._fired]
            if dead:
                self._fired.update(dead)
                self.on_dead(dead)
            time.sleep(self.poll_s)

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2.0)

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
        return False


@dataclass
class StragglerMonitor:
    """EWMA + windowed-median step timing; flags outlier steps/hosts."""
    threshold: float = 2.0
    window: int = 64
    _times: deque = field(default_factory=lambda: deque(maxlen=64))
    ewma: float = 0.0
    flagged: int = 0

    def record(self, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        self._times.append(seconds)
        self.ewma = seconds if self.ewma == 0.0 \
            else 0.9 * self.ewma + 0.1 * seconds
        med = sorted(self._times)[len(self._times) // 2]
        is_straggler = len(self._times) >= 8 and seconds > self.threshold * med
        if is_straggler:
            self.flagged += 1
        return is_straggler

    def median(self) -> float:
        return sorted(self._times)[len(self._times) // 2] \
            if self._times else 0.0


def run_restartable(body: Callable[[int], int], *,
                    restore: Callable[[], int],
                    max_restarts: int = 3) -> int:
    """Crash-loop driver.

    ``body(start_step)`` runs the training loop and returns the final step
    (raising on simulated/real failure); ``restore()`` reloads the latest
    checkpoint and returns the step to resume from.
    """
    restarts = 0
    start = restore()
    while True:
        try:
            return body(start)
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            start = restore()
