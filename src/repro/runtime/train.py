"""The distributed training step.

``make_train_step`` builds a jit-able ``(params, opt, batch) -> (params,
opt, metrics)`` closure with:

  * activation rematerialization (per-layer-run ``jax.checkpoint`` inside
    the model's scan bodies),
  * gradient accumulation over ``grad_accum`` microbatches (a ``lax.scan``
    over the leading split of the batch, so peak activation memory is one
    microbatch),
  * buffer donation of params/opt (declared by the caller at jit time),
  * optional int8 error-feedback gradient compression for the DP all-reduce
    (enabled via ``compress_grads``; carried state rides in the opt pytree).

Sharding is *not* decided here: the launcher derives in/out shardings from
``distributed.param_specs`` / ``batch_spec`` and passes them to jit, and
GSPMD propagates everything else — including turning the weight-sharded
(FSDP) dims into all-gathers and the DP gradient reduction into
reduce-scatters where profitable.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.optim.adamw import AdamWState, adamw_update


class TrainStep(NamedTuple):
    fn: Callable          # (params, opt, batch) -> (params, opt, metrics)
    grad_accum: int


def _split_microbatches(batch: dict, n: int) -> dict:
    """(B, ...) -> (n, B/n, ...) on every leaf."""
    def r(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by grad_accum {n}"
        return x.reshape((n, b // n) + x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(cfg: ArchConfig, *, lr_fn: Callable[[jax.Array],
                                                        jax.Array],
                    grad_accum: int = 1, remat: bool = True,
                    factored: bool = False,
                    weight_decay: float = 0.1,
                    clip_norm: Optional[float] = 1.0) -> TrainStep:

    def loss_fn(params, microbatch):
        return M.train_forward(params, cfg, microbatch, remat=remat)

    grad_fn = jax.value_and_grad(loss_fn)

    def step(params, opt: AdamWState, batch: dict):
        if grad_accum == 1:
            loss, grads = grad_fn(params, batch)
        else:
            micro = _split_microbatches(batch, grad_accum)

            def accum(carry, mb):
                g_acc, l_acc = carry
                l, g = grad_fn(params, mb)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                accum, (zero, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum

        lr = lr_fn(opt.step)
        params, opt, om = adamw_update(
            grads, opt, params, lr=lr, weight_decay=weight_decay,
            clip_norm=clip_norm, factored=factored)
        metrics = {"loss": loss, "lr": lr, **om}
        return params, opt, metrics

    return TrainStep(fn=step, grad_accum=grad_accum)


def param_struct(cfg: ArchConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree of the parameters (no allocation) — the
    dry-run stand-in produced by ``jax.eval_shape`` over init."""
    return jax.eval_shape(
        functools.partial(M.init_params, cfg, dtype=dtype),
        jax.random.PRNGKey(0))


def opt_struct(params_struct, factored: bool = False):
    from repro.optim.adamw import adamw_init
    return jax.eval_shape(
        functools.partial(adamw_init, factored=factored), params_struct)
