"""Device-resident stacked-operand cache with epoch-based slice refresh.

The batched cross-shard kernels (``kernels/eh_lookup.sharded_*``, the KV
manager's cross-shard ``get_context``) consume the per-shard structures
stacked on a leading shard axis: ``(N, ...)`` directories, bucket pools,
composed views.  Re-materializing those stacks per batch — the original
``jnp.stack([...])`` in every lookup — is an O(total index size) copy
that dwarfs the probe it feeds, and it is exactly the cost the paper's
§4 rewiring exists to eliminate: pay the mapping once at *publish* time,
not on every lookup.  (Paged-attention serving stacks make the same
move: the block tables stay device-resident and only dirty slices are
patched per step.)

:class:`StackedOperandCache` keeps one stacked tuple per *operand
family* ("eh_trad", "eh_view", "kv_view", ...) resident on device, keyed
by per-shard **publish epochs**:

  * every authoritative mutation / view publication bumps its shard's
    epoch *after* storing the new arrays (writer order; the hooks live
    in ``runtime/mapper.ShortcutMapper`` and
    ``runtime/shard_group.ShardViewRegistry``);
  * a reader passes the epochs it read *before* snapshotting the
    per-shard arrays; the cache refreshes only the shards whose epoch
    moved, with one ``jax.lax.dynamic_update_slice`` per dirty shard —
    O(changed shards), not O(index);
  * a dirty shard whose part changed **shape** (e.g. a composed view
    after a directory doubling grew past the common pad capacity)
    triggers a full rebuild of that family — the only O(index) path
    left, and it is amortized over every doubling interval.

The reader/writer epoch protocol tolerates races in exactly one
direction: a publication landing between the reader's epoch read and its
array snapshot hands the cache *newer* arrays under an *older* recorded
epoch, so the next ``get`` refreshes redundantly — never serves stale.
The hooks bump epochs **before** publishing ``sc_version`` (see
``ShortcutMapper._process``), so any view a version gate certifies is
already visible as a dirty epoch: a cached slice older than the epoch
the gate certified cannot be served.

Donation/aliasing rules (DESIGN.md §4.3): with ``donate=True`` the
refresh donates the previous stacked buffer to the update-slice call on
accelerator backends, so XLA patches it in place instead of allocating
a sibling copy.  Donation deletes the old buffer, which makes every
returned stack a **loan** whose lifetime ends at the next refresh — a
reader that obtained a stack and races another thread's refresh before
dispatching observes a deleted buffer.  That is only safe when a single
thread drives lookups (the common serving-loop shape), so donation is
**opt-in**: the default never donates and is safe for concurrent
readers (each refresh allocates a sibling; old loans stay valid until
released).  CPU donation would be a warn-and-copy no-op either way, so
the interpret-mode tests cannot exercise the donating path — another
reason it must not be the silent default.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = ["StackedOperandCache", "OperandCacheStats"]


def _backend_can_donate() -> bool:
    """XLA implements input/output aliasing on accelerators only; CPU
    donation is a warn-and-copy no-op."""
    return jax.default_backend() in ("tpu", "gpu")


@jax.jit
def _refresh_slice(stacked: jax.Array, part: jax.Array,
                   shard: jax.Array) -> jax.Array:
    """stacked[shard] = part, via dynamic_update_slice (shard is traced,
    so N shards share one compiled variant per shape/dtype)."""
    start = (shard.astype(jnp.int32),) + (jnp.int32(0),) * part.ndim
    return jax.lax.dynamic_update_slice(stacked, part[None], start)


# donating twin: same computation, previous stack buffer reused in place
_refresh_slice_donated = jax.jit(
    lambda stacked, part, shard: _refresh_slice.__wrapped__(
        stacked, part, shard),
    donate_argnums=(0,))


@dataclass
class OperandCacheStats:
    hits: int = 0               # get() served fully from cache (0 dirty)
    slice_refreshes: int = 0    # dirty shards patched in place
    rebuilds: int = 0           # full restacks (first build / shape change)

    def snapshot(self) -> "OperandCacheStats":
        return OperandCacheStats(self.hits, self.slice_refreshes,
                                 self.rebuilds)


@dataclass
class _Entry:
    epochs: List[int]                       # per-shard epoch of each slice
    arrays: Tuple[jax.Array, ...]           # the stacked (N, ...) tensors
    part_shapes: Tuple[tuple, ...]          # per-shard part shapes/dtypes
    part_dtypes: Tuple = field(default_factory=tuple)


class StackedOperandCache:
    """Per-family cache of stacked ``(N, ...)`` lookup operands.

    ``get(family, epochs, parts)`` is the single entry point: ``epochs``
    are the per-shard publish epochs the caller read *before* taking its
    array snapshots, and ``parts`` is a callable ``shard -> tuple of
    device arrays`` invoked **only** for dirty shards (or all shards on
    a rebuild) — so a clean get never touches per-shard arrays at all.
    Part tuples must be shape/dtype-uniform across shards within one
    call; a caller whose parts grew (view doubling) simply returns the
    new shape and the family rebuilds.

    Thread safety: one lock per cache serializes refreshes; concurrent
    readers either wait for the patch or hit the already-updated entry.
    Writers (mappers) never call in here — they only bump epochs.

    ``donate=True`` opts into in-place refreshes on accelerator
    backends (see the module docstring's aliasing rules): only for
    single-reader drivers — a donating refresh deletes the buffers a
    concurrent reader may still be about to dispatch with.
    """

    def __init__(self, num_shards: int, *, donate: bool = False):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        self.num_shards = num_shards
        self.donate = bool(donate)
        self.stats = OperandCacheStats()
        self._entries: Dict[str, _Entry] = {}
        self._lock = threading.Lock()

    # -- the hot path --------------------------------------------------------

    def get(self, family: str, epochs: Sequence[int],
            parts: Callable[[int], Tuple[jax.Array, ...]]
            ) -> Tuple[jax.Array, ...]:
        """Stacked operand tuple for ``family``, current to ``epochs``."""
        epochs = [int(e) for e in epochs]
        if len(epochs) != self.num_shards:
            raise ValueError(f"{len(epochs)} epochs for "
                             f"{self.num_shards} shards")
        with self._lock:
            ent = self._entries.get(family)
            if ent is None:
                return self._rebuild(family, epochs, parts)
            dirty = [s for s in range(self.num_shards)
                     if epochs[s] != ent.epochs[s]]
            if not dirty:
                self.stats.hits += 1
                return ent.arrays
            arrays = list(ent.arrays)
            new_epochs = list(ent.epochs)
            refresh = (_refresh_slice_donated
                       if self.donate and _backend_can_donate()
                       else _refresh_slice)
            try:
                for s in dirty:
                    p = tuple(parts(s))
                    if (tuple(a.shape for a in p) != ent.part_shapes
                            or tuple(a.dtype for a in p)
                            != ent.part_dtypes):
                        # shape changed (e.g. view doubling): restack
                        return self._rebuild(family, epochs, parts,
                                             prebuilt={s: p})
                    sidx = jnp.int32(s)
                    for j, a in enumerate(p):
                        arrays[j] = refresh(arrays[j], a, sidx)
                    new_epochs[s] = epochs[s]
                    self.stats.slice_refreshes += 1
            except BaseException:
                if refresh is _refresh_slice_donated:
                    # the old buffers may already be donated away; drop
                    # the entry so the next get rebuilds from scratch
                    self._entries.pop(family, None)
                raise
            # commit epochs and arrays together, only once every dirty
            # slice refreshed — a parts() exception mid-loop must not
            # leave the entry claiming freshness over the old arrays
            ent.arrays = tuple(arrays)
            ent.epochs = new_epochs
            return ent.arrays

    def _rebuild(self, family: str, epochs: List[int],
                 parts: Callable[[int], Tuple[jax.Array, ...]],
                 prebuilt: Optional[dict] = None) -> Tuple[jax.Array, ...]:
        prebuilt = prebuilt or {}
        per_shard = [tuple(prebuilt.get(s) or parts(s))
                     for s in range(self.num_shards)]
        width = {len(p) for p in per_shard}
        if len(width) != 1:
            raise ValueError(f"family {family!r}: ragged part tuples "
                             f"{sorted(width)}")
        stacked = tuple(jnp.stack([p[j] for p in per_shard])
                        for j in range(width.pop()))
        self._entries[family] = _Entry(
            epochs=list(epochs), arrays=stacked,
            part_shapes=tuple(a.shape for a in per_shard[0]),
            part_dtypes=tuple(a.dtype for a in per_shard[0]))
        self.stats.rebuilds += 1
        return stacked

    # -- bookkeeping ---------------------------------------------------------

    def epochs(self, family: str) -> Optional[List[int]]:
        """The per-shard epochs the cached slices were built at (test /
        introspection hook); None before the family's first build."""
        ent = self._entries.get(family)
        return None if ent is None else list(ent.epochs)

    def invalidate(self, family: Optional[str] = None) -> None:
        """Drop one family (or all) — next get() rebuilds."""
        with self._lock:
            if family is None:
                self._entries.clear()
            else:
                self._entries.pop(family, None)

    def __contains__(self, family: str) -> bool:
        return family in self._entries
