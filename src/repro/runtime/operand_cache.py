"""Publish-owned stacked lookup operands: pay the patch at publish time.

The batched cross-shard kernels (``kernels/eh_lookup.sharded_*``, the KV
manager's cross-shard ``get_context``) consume the per-shard structures
stacked on a leading shard axis: ``(N, ...)`` directories, bucket pools,
composed views.  The first cache generation (PR 4) kept those stacks as
a *secondary* copy: replays published per-shard arrays, and the first
lookup after a publish patched the dirty slice with a
``dynamic_update_slice`` — lazy refresh on the read path, every cached
family resident twice (per-shard originals plus the stack).

This module inverts the ownership, which is the paper's §4 move applied
one level up: pay the mapping cost when the mapping *changes* (page
table rewiring at create/split time) so the common-case read does no
fix-up work at all (cf. Utopia's restrictive mappings, PAPERS.md).

  * The stacked ``(N, ...)`` device buffers are the **primary** storage.
    Writers — mapper replay threads, the KV view registry — call
    :meth:`StackedOperandCache.publish` from the *mapper thread* at
    publish time, **before** ``sc_version`` is published: one
    ``dynamic_update_slice`` per part, donated in place on accelerator
    backends.
  * The lookup path (:meth:`get` with no ``parts``) is an epoch
    comparison plus a handle return — zero device work in steady state.
  * Per-shard reads (``view_snapshot``, a replay's read-modify-write)
    go through :meth:`slice_of`, a memoized slice of the stack — the
    per-shard original arrays of cached families are deleted, not
    duplicated.  The memo is identity-keyed on the stacked tuple, so it
    costs one slice copy per publish, not per read.
  * A part that outgrows the stacked extent (directory doubling, view
    growth past the common capacity) triggers a **background re-stack**
    on the publishing thread: the old stack is embedded into a freshly
    zeroed larger stack with one ``dynamic_update_slice`` and swapped
    atomically — readers holding the old handle stay valid and are
    never blocked (the shard-level analogue of a directory doubling).

Epoch protocol (client-domain epochs): every entry records, per shard,
the highest *client* epoch published into it (``ShortcutMapper``'s
``view_epoch`` / ``trad_epoch`` domains).  A reader passes the epochs it
read **before** the call; the entry is clean for shard ``s`` when
``entry.epochs[s] >= reader_epochs[s]``.  Races are tolerated in exactly
one direction: a publish landing between the reader's epoch read and its
``get`` makes the entry *newer* than requested — served as a hit, which
is correct because publication order (arrays first, then epoch; both
before ``sc_version``) guarantees any gate-certified view is already in
the stack.  A push-owned family that *lags* the reader's epochs is a
writer-order violation and raises rather than serving stale data.

Pull-mode families remain supported for operands whose authoritative
state lives client-side (the "eh_trad" bucket arrays): ``get`` with a
``parts`` callable patches dirty shards on the read path (counted as
``lookup_refreshes``), and the client may keep the family warm
afterwards with :meth:`publish_if_present` at mutation time.

Donation/aliasing rules (DESIGN.md §4.3/§4.4): with ``donate=True`` the
publish donates the previous stacked buffer to the update-slice call on
accelerator backends, so XLA patches it in place instead of allocating a
sibling copy.  Donation deletes the old buffer, which makes every
returned stack a **loan** whose lifetime ends at the next publish — only
safe when a single thread drives lookups.  It is therefore opt-in; the
default never donates and is safe for concurrent readers (old loans and
memoized slices stay valid until released).  CPU donation would be a
warn-and-copy no-op either way, so the interpret-mode tests cannot
exercise the donating path.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = ["StackedOperandCache", "OperandCacheStats"]


def _backend_can_donate() -> bool:
    """XLA implements input/output aliasing on accelerators only; CPU
    donation is a warn-and-copy no-op."""
    return jax.default_backend() in ("tpu", "gpu")


@jax.jit
def _refresh_slice(stacked: jax.Array, part: jax.Array,
                   shard: jax.Array) -> jax.Array:
    """stacked[shard] = part, via dynamic_update_slice (shard is traced,
    so N shards share one compiled variant per shape/dtype)."""
    start = (shard.astype(jnp.int32),) + (jnp.int32(0),) * part.ndim
    return jax.lax.dynamic_update_slice(stacked, part[None], start)


# donating twin: same computation, previous stack buffer reused in place
_refresh_slice_donated = jax.jit(
    lambda stacked, part, shard: _refresh_slice.__wrapped__(
        stacked, part, shard),
    donate_argnums=(0,))


@jax.jit
def _embed_stack(dst: jax.Array, src: jax.Array) -> jax.Array:
    """Place the whole old stack at the origin of a larger zeroed stack
    (the re-stack-on-growth path; one update-slice, shapes are static)."""
    return jax.lax.dynamic_update_slice(
        dst, src, (jnp.int32(0),) * src.ndim)


@dataclass
class OperandCacheStats:
    hits: int = 0                # get() served from the stack (no device work)
    publish_refreshes: int = 0   # slices patched at publish time (writer side)
    lookup_refreshes: int = 0    # slices patched on the lookup path (pull mode)
    rebuilds: int = 0            # full (re)stacks: first build / shape growth
    resident: Dict[str, int] = field(default_factory=dict)  # bytes per family

    @property
    def slice_refreshes(self) -> int:
        """Total slice patches, either side (back-compat aggregate)."""
        return self.publish_refreshes + self.lookup_refreshes

    def snapshot(self) -> "OperandCacheStats":
        return OperandCacheStats(self.hits, self.publish_refreshes,
                                 self.lookup_refreshes, self.rebuilds,
                                 dict(self.resident))


@dataclass
class _Entry:
    epochs: List[int]                    # per-shard client epoch of each slice
    arrays: Tuple[jax.Array, ...]        # the stacked (N, ...) tensors
    part_shapes: Tuple[tuple, ...]       # per-shard extents (without N axis)
    part_dtypes: Tuple = field(default_factory=tuple)
    published: List[bool] = field(default_factory=list)  # shard has real data


class StackedOperandCache:
    """Primary storage of stacked ``(N, ...)`` lookup operands.

    Push-owned families ("eh_view", "kv_view"): writers call
    :meth:`publish` per shard from the mapper thread before the shard's
    ``sc_version`` moves; the lookup path calls ``get(family, epochs)``
    with no parts and receives the stacked handle after a pure epoch
    check.  Pull-mode families ("eh_trad"): ``get(family, epochs,
    parts)`` patches dirty shards on the read path, exactly the PR 4
    contract, and mutators may keep the stack warm with
    :meth:`publish_if_present`.

    Thread safety: one lock serializes all mutation (publish, pull
    refresh, re-stack); the push-mode ``get`` and :meth:`slice_of` are
    lock-free — they read the entry's epoch list before its arrays
    tuple, the writer stores arrays before epochs, and both stores are
    GIL-atomic, so a racing reader can only observe newer-arrays-than-
    epoch (a hit it was entitled to), never the reverse.

    ``donate=True`` opts into in-place publishes on accelerator backends
    (see the module docstring's aliasing rules): single-reader drivers
    only — a donating publish deletes the buffers a concurrent reader
    may still be about to dispatch with.
    """

    def __init__(self, num_shards: int, *, donate: bool = False):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        self.num_shards = num_shards
        self.donate = bool(donate)
        self.stats = OperandCacheStats()
        self._entries: Dict[str, _Entry] = {}
        # identity-keyed per-(family, shard) slice memo: one slice copy
        # per publish, not per snapshot read
        self._slices: Dict[tuple, tuple] = {}
        self._lock = threading.Lock()

    # -- the lookup path -----------------------------------------------------

    def get(self, family: str, epochs: Sequence[int],
            parts: Optional[Callable[[int], Tuple[jax.Array, ...]]] = None
            ) -> Tuple[jax.Array, ...]:
        """Stacked operand tuple for ``family``, current to ``epochs``.

        Without ``parts`` (push-owned family) this is the zero-copy hot
        path: epoch comparison + handle return, lock-free; a lagging
        entry is a writer-order violation and raises.  With ``parts``
        (pull mode) dirty shards are patched here and counted as
        ``lookup_refreshes``."""
        epochs = [int(e) for e in epochs]
        if len(epochs) != self.num_shards:
            raise ValueError(f"{len(epochs)} epochs for "
                             f"{self.num_shards} shards")
        ent = self._entries.get(family)
        if ent is not None:
            eps = ent.epochs              # epochs BEFORE arrays (see class doc)
            if all(eps[s] >= epochs[s] for s in range(self.num_shards)):
                self.stats.hits += 1
                return ent.arrays
        if parts is None:
            lag = ([] if ent is None else
                   [s for s in range(self.num_shards)
                    if ent.epochs[s] < epochs[s]])
            raise RuntimeError(
                f"operand family {family!r} is publish-owned but "
                f"{'was never published' if ent is None else f'lags the reader on shards {lag}'}"
                f": publish() must run on the mapper thread before "
                f"sc_version is published (writer-order violation)")
        with self._lock:
            ent = self._entries.get(family)
            if ent is None:
                return self._rebuild(family, epochs, parts)
            dirty = [s for s in range(self.num_shards)
                     if epochs[s] > ent.epochs[s]]
            if not dirty:
                self.stats.hits += 1
                return ent.arrays
            arrays = list(ent.arrays)
            new_epochs = list(ent.epochs)
            refresh = self._refresh_fn()
            try:
                for s in dirty:
                    p = tuple(parts(s))
                    if (tuple(a.shape for a in p) != ent.part_shapes
                            or tuple(a.dtype for a in p)
                            != ent.part_dtypes):
                        # shape changed (e.g. directory growth): restack
                        return self._rebuild(family, epochs, parts,
                                             prebuilt={s: p})
                    sidx = jnp.int32(s)
                    for j, a in enumerate(p):
                        arrays[j] = refresh(arrays[j], a, sidx)
                    new_epochs[s] = max(new_epochs[s], epochs[s])
                    self.stats.lookup_refreshes += 1
            except BaseException:
                if refresh is _refresh_slice_donated:
                    # the old buffers may already be donated away; drop
                    # the entry so the next get rebuilds from scratch
                    self._drop(family)
                raise
            # commit arrays before epochs, only once every dirty slice
            # refreshed — a parts() exception mid-loop must not leave
            # the entry claiming freshness over the old arrays
            for s in dirty:
                ent.published[s] = True
            ent.arrays = tuple(arrays)
            ent.epochs = new_epochs
            return ent.arrays

    # -- the publish path (writer side, mapper thread) -----------------------

    def publish(self, family: str, shard: int,
                parts: Sequence[jax.Array], *, epoch: int) -> None:
        """Write one shard's operand tuple straight into the stack.

        Called from the shard's mapper thread (or the ``pump()`` caller)
        **before** the shard's ``sc_version`` is published, carrying the
        client epoch the publication corresponds to (the mapper's
        ``next_view_epoch`` during a replay).  Creates the family on
        first publish (other shards start zeroed and unpublished); grows
        the stacked extent in place when the part outgrew it; pads a
        smaller part up to the extent (rows past the shard's own logical
        size are never indexed — the kernels slot by per-shard
        depth/log2 operands)."""
        parts = tuple(parts)
        if not 0 <= shard < self.num_shards:
            raise ValueError(f"shard {shard} of {self.num_shards}")
        if not parts:
            raise ValueError(f"family {family!r}: empty part tuple")
        with self._lock:
            ent = self._entries.get(family)
            if ent is None:
                ent = self._create_zeroed(family, parts)
            if len(parts) != len(ent.arrays):
                raise ValueError(
                    f"family {family!r}: {len(parts)} parts for a "
                    f"{len(ent.arrays)}-part family")
            if tuple(a.dtype for a in parts) != ent.part_dtypes:
                raise ValueError(f"family {family!r}: part dtypes changed")
            shapes = tuple(tuple(a.shape) for a in parts)
            if any(len(s) != len(e)
                   for s, e in zip(shapes, ent.part_shapes)):
                raise ValueError(f"family {family!r}: part ranks changed")
            if any(d > e for sh, ext in zip(shapes, ent.part_shapes)
                   for d, e in zip(sh, ext)):
                self._restack_grow(family, ent, shapes)
            parts = tuple(self._pad_to_extent(a, ext)
                          for a, ext in zip(parts, ent.part_shapes))
            refresh = self._refresh_fn()
            arrays = list(ent.arrays)
            sidx = jnp.int32(shard)
            try:
                for j, a in enumerate(parts):
                    arrays[j] = refresh(arrays[j], a, sidx)
            except BaseException:
                if refresh is _refresh_slice_donated:
                    self._drop(family)
                raise
            ent.arrays = tuple(arrays)     # arrays first, then epoch
            ent.published[shard] = True
            ent.epochs[shard] = max(ent.epochs[shard], int(epoch))
            self.stats.publish_refreshes += 1

    def publish_if_present(self, family: str, shard: int,
                           parts: Callable[[], Tuple[jax.Array, ...]], *,
                           epoch: int) -> None:
        """Keep a pull-built family warm from the mutation path: publish
        only when the family already exists (a lookup built it), so a
        write-heavy phase that never routes through the family pays
        nothing for it."""
        if family in self._entries:
            self.publish(family, shard, tuple(parts()), epoch=epoch)

    def touch(self, family: str, shard: int, *, epoch: int) -> None:
        """Advance a shard's epoch without new data — a replay whose
        merged work was empty (nothing stale) still owes the reader an
        epoch so the entry never lags a gate-certified version."""
        with self._lock:
            ent = self._entries.get(family)
            if ent is not None:
                ent.epochs[shard] = max(ent.epochs[shard], int(epoch))

    def seed(self, family: str, per_shard_parts: Sequence[Sequence], *,
             epoch: int = 0) -> None:
        """Build a family in one shot from uniform per-shard part tuples
        (init path — e.g. the KV manager's zeroed views); every shard is
        marked published at ``epoch``."""
        per = [tuple(p) for p in per_shard_parts]
        if len(per) != self.num_shards:
            raise ValueError(f"{len(per)} part tuples for "
                             f"{self.num_shards} shards")
        with self._lock:
            widths = {len(p) for p in per}
            if len(widths) != 1:
                raise ValueError(f"family {family!r}: ragged part tuples "
                                 f"{sorted(widths)}")
            stacked = tuple(jnp.stack([p[j] for p in per])
                            for j in range(widths.pop()))
            self._install(family, _Entry(
                epochs=[int(epoch)] * self.num_shards, arrays=stacked,
                part_shapes=tuple(tuple(a.shape) for a in per[0]),
                part_dtypes=tuple(a.dtype for a in per[0]),
                published=[True] * self.num_shards))

    # -- per-shard views of the stack ---------------------------------------

    def handle(self, family: str) -> Optional[Tuple[jax.Array, ...]]:
        """The stacked tuple itself (or None) — no epoch check; the
        population hook (``view_arrays``) and tests use this."""
        ent = self._entries.get(family)
        return None if ent is None else ent.arrays

    def slice_of(self, family: str, shard: int
                 ) -> Optional[Tuple[jax.Array, ...]]:
        """One shard's operand tuple as slices of the stack — the only
        per-shard materialization left (``view_snapshot``, replay
        read-modify-write).  Memoized on the stacked tuple's identity:
        steady-state snapshots return the cached slices with zero device
        work; the copy is paid once per publish.  Internally consistent
        by construction — every array comes from ONE stacked tuple."""
        ent = self._entries.get(family)
        if ent is None:
            return None
        arrays = ent.arrays                      # single read: swap is atomic
        key = (family, shard)
        memo = self._slices.get(key)
        if memo is not None and memo[0] is arrays:
            return memo[1]
        sl = tuple(a[shard] for a in arrays)
        self._slices[key] = (arrays, sl)
        return sl

    def published(self, family: str) -> Optional[List[bool]]:
        """Per-shard "holds real data" flags (False = still the zeroed
        placeholder); None before the family exists."""
        ent = self._entries.get(family)
        return None if ent is None else list(ent.published)

    # -- bookkeeping ---------------------------------------------------------

    def epochs(self, family: str) -> Optional[List[int]]:
        """The per-shard client epochs the cached slices are current to
        (test / introspection hook); None before the family exists."""
        ent = self._entries.get(family)
        return None if ent is None else list(ent.epochs)

    def resident_bytes(self) -> Dict[str, int]:
        """Device bytes resident per family (the stacks are the primary
        and only persistent storage; memoized slices are transient)."""
        return dict(self.stats.resident)

    def invalidate(self, family: Optional[str] = None) -> None:
        """Drop one family (or all).  A push-owned family loses its
        derived data: shards read as unpublished (clients demote to
        their traditional/paged route) until their next create replay
        republishes; a pull family simply rebuilds on the next get."""
        with self._lock:
            for fam in ([family] if family is not None
                        else list(self._entries)):
                self._drop(fam)

    def __contains__(self, family: str) -> bool:
        return family in self._entries

    # -- internals (call with self._lock held) -------------------------------

    def _refresh_fn(self):
        return (_refresh_slice_donated
                if self.donate and _backend_can_donate()
                else _refresh_slice)

    def _install(self, family: str, ent: _Entry) -> None:
        self._entries[family] = ent
        self.stats.rebuilds += 1
        self.stats.resident[family] = sum(int(a.nbytes) for a in ent.arrays)

    def _drop(self, family: str) -> None:
        self._entries.pop(family, None)
        self.stats.resident.pop(family, None)
        for s in range(self.num_shards):
            self._slices.pop((family, s), None)

    def _create_zeroed(self, family: str, parts: Tuple) -> _Entry:
        stacked = tuple(
            jnp.zeros((self.num_shards,) + tuple(a.shape), a.dtype)
            for a in parts)
        ent = _Entry(
            epochs=[0] * self.num_shards, arrays=stacked,
            part_shapes=tuple(tuple(a.shape) for a in parts),
            part_dtypes=tuple(a.dtype for a in parts),
            published=[False] * self.num_shards)
        self._install(family, ent)
        return ent

    def _restack_grow(self, family: str, ent: _Entry,
                      shapes: Tuple[tuple, ...]) -> None:
        """Background re-stack on growth: embed the old stack into a
        larger zeroed one (elementwise-max extents) and swap atomically.
        Runs on the publishing thread; readers holding the old handle
        are never blocked and never see a torn stack."""
        new_ext = tuple(tuple(max(d, e) for d, e in zip(sh, ext))
                        for sh, ext in zip(shapes, ent.part_shapes))
        grown = []
        for old, ext in zip(ent.arrays, new_ext):
            if tuple(old.shape[1:]) == ext:
                grown.append(old)
                continue
            dst = jnp.zeros((self.num_shards,) + ext, old.dtype)
            grown.append(_embed_stack(dst, old))
        ent.arrays = tuple(grown)
        ent.part_shapes = new_ext
        self.stats.rebuilds += 1
        self.stats.resident[family] = sum(int(a.nbytes) for a in grown)

    @staticmethod
    def _pad_to_extent(a: jax.Array, ext: tuple) -> jax.Array:
        if tuple(a.shape) == tuple(ext):
            return a
        return jnp.pad(a, [(0, e - d) for d, e in zip(a.shape, ext)])

    def _rebuild(self, family: str, epochs: List[int],
                 parts: Callable[[int], Tuple[jax.Array, ...]],
                 prebuilt: Optional[dict] = None) -> Tuple[jax.Array, ...]:
        """Pull-mode full (re)stack: first build of a pull family, or a
        shape change discovered on the read path."""
        prebuilt = prebuilt or {}
        per_shard = [tuple(prebuilt.get(s) or parts(s))
                     for s in range(self.num_shards)]
        width = {len(p) for p in per_shard}
        if len(width) != 1:
            raise ValueError(f"family {family!r}: ragged part tuples "
                             f"{sorted(width)}")
        stacked = tuple(jnp.stack([p[j] for p in per_shard])
                        for j in range(width.pop()))
        self._install(family, _Entry(
            epochs=list(epochs), arrays=stacked,
            part_shapes=tuple(tuple(a.shape) for a in per_shard[0]),
            part_dtypes=tuple(a.dtype for a in per_shard[0]),
            published=[True] * self.num_shards))
        return stacked
