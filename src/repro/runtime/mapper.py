"""Generic shortcut-maintenance runtime (paper §3–§4.1, factored out).

The paper's core mechanism is *one* pattern instantiated per structure:
an authoritative ("traditional") structure is modified synchronously by
the main thread, while a *shortcut view* of it is rewired asynchronously
by a mapper thread that polls a FIFO of maintenance requests —

  * ``update`` requests replay small, incremental rewirings (the per-slot
    ``mmap(MAP_SHARED|MAP_FIXED)`` calls of §3.3);
  * ``create`` requests rebuild a view from scratch (the ``mmap`` loop of
    step (2)) and make any *older* pending updates for the same view
    redundant — the runtime collapses them;
  * the view is eagerly *populated* (``block_until_ready``, the page-table
    population analogue of §3.1) before its version is published;
  * reads route through the shortcut only when it is **in sync**
    (version gate) *and* a structure-specific cost statistic says the
    shortcut actually pays (fan-in for EH §3.2, fragmentation for the KV
    cache, chain length for the prefix index) — a pluggable
    :class:`RoutingPolicy`.

This module owns all of that machinery *generically*: the FIFO queue,
the create-collapses-older-updates batching, the mapper thread and its
synchronous surrogate :meth:`ShortcutMapper.pump`, per-view-key version
bookkeeping, eager population, :class:`MaintenanceStats`, and routing.
Clients (``core/shortcut_eh.py``, ``kvcache/shortcut_cache.py``, the
prefix shortcut in ``kvcache/prefix_index.py``) supply only the replay
callables that know how to rebuild/patch their particular view — see
DESIGN.md §4.

Versioning model: the runtime keeps ``trad_version[key]`` and
``sc_version[key]`` per *view key*.  A structure with one global view
(Shortcut-EH) uses the single key :data:`GLOBAL_VIEW`; a structure with
many independent sub-views (one per sequence in the KV cache) uses one
key per sub-view.  ``trad_version`` starts at 0 and is bumped under the
runtime's lock together with the authoritative mutation; ``sc_version``
starts at -1 ("never populated") and is published monotonically after
replay + population.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Hashable, Iterable, Optional, Sequence

#: View key for clients that maintain a single, global shortcut view.
GLOBAL_VIEW: Hashable = "__global__"

CREATE = "create"
UPDATE = "update"


@dataclass
class Request:
    """One maintenance request in the FIFO.

    ``versions`` maps each view key the request touches to the
    ``trad_version`` that replaying it brings the shortcut to."""
    kind: str                      # CREATE | UPDATE
    versions: dict                 # view key -> target trad_version
    payload: Any = None            # client data (touched buckets, rows, ...)


@dataclass
class MaintenanceStats:
    creates: int = 0               # create replay batches
    updates: int = 0               # update replay batches
    collapsed: int = 0             # update requests made redundant by creates
    slots_remapped: int = 0        # client-reported rewired slots/rows
    replay_seconds: float = 0.0
    populate_seconds: float = 0.0


# ---------------------------------------------------------------------------
# Routing policies: the structure-specific "is the shortcut worth it" law.
# ---------------------------------------------------------------------------

@dataclass
class FanInRouting:
    """EH's law (§3.2): route shortcut while the average directory fan-in
    is at most ``threshold`` (paper: 8).  Above it the shortcut's virtual
    footprint (2^g pages vs 2^g pointers + m pages) thrashes the TLB
    analogue and the traditional path is cheaper."""
    threshold: float = 8.0

    def decide(self, metric: float) -> bool:
        return metric <= self.threshold


@dataclass
class FragmentationRouting:
    """The KV cache's law: route shortcut once batch fragmentation is at
    least ``threshold`` — below it the paged gather streams
    nearly-contiguous blocks anyway and maintenance is pure overhead."""
    threshold: float = 0.25

    def decide(self, metric: float) -> bool:
        return metric >= self.threshold


class HysteresisRouting:
    """Sticky wrapper: flip to the shortcut only when ``enter`` fires,
    flip back only when ``exit`` stops firing; hold in between.

    Prevents route flapping when the metric oscillates around a single
    threshold (e.g. fan-in bouncing across 8.0 as splits land): configure
    ``enter`` stricter than ``exit`` — say ``FanInRouting(6)`` to enter
    and ``FanInRouting(10)`` to stay.
    """

    def __init__(self, enter, exit_):
        self.enter = enter
        self.exit = exit_
        self.engaged = False

    def decide(self, metric: float) -> bool:
        self.engaged = (self.exit.decide(metric) if self.engaged
                        else self.enter.decide(metric))
        return self.engaged


# ---------------------------------------------------------------------------
# The runtime.
# ---------------------------------------------------------------------------

class ShortcutMapper:
    """Owns queue, mapper thread, versioning, routing and stats for one
    shortcut view family.

    Parameters
    ----------
    replay_create / replay_update:
        ``f(snapshot, requests)`` — replay a FIFO-ordered run of same-kind
        requests against the client's view.  ``snapshot`` is whatever
        ``snapshot()`` returned under the runtime lock at batch start.
    snapshot:
        ``f()`` — return a consistent reference to the authoritative
        structure; called under :attr:`lock`.
    view_arrays:
        ``f()`` — iterable of device arrays to eagerly populate
        (``block_until_ready``) before versions are published.
    routing:
        a :class:`RoutingPolicy` (``decide(metric) -> bool``).
    async_mapper:
        run the paper's polling mapper thread; otherwise callers drive
        maintenance synchronously via :meth:`pump`.
    """

    def __init__(self, *, replay_create: Callable[[Any, list], None],
                 replay_update: Callable[[Any, list], None],
                 snapshot: Callable[[], Any],
                 view_arrays: Callable[[], Iterable],
                 routing, poll_interval: float = 0.025,
                 async_mapper: bool = False, name: str = "shortcut-mapper"):
        self._replay_create = replay_create
        self._replay_update = replay_update
        self._snapshot = snapshot
        self._view_arrays = view_arrays
        self.routing = routing
        self.poll_interval = float(poll_interval)
        self.stats = MaintenanceStats()
        self.routed_shortcut = 0
        self.routed_fallback = 0
        self.lock = threading.Lock()
        # serializes _process between the mapper thread and pump()
        # callers: replay callables do unguarded read-modify-writes of
        # their view slots (single-writer protocol), so two concurrent
        # _process calls on the SAME mapper would silently lose the
        # earlier publication.  Per-mapper only — shards never share it.
        self._replay_mutex = threading.Lock()
        # publish epochs for the device-resident operand cache
        # (runtime/operand_cache.py): trad_epoch moves with every
        # authoritative mutation (record/invalidate), view_epoch with
        # every replay-batch publication.  Writer order is always
        # "publish operands, then bump" — replay callables push their
        # results into the stacked cache at :attr:`next_view_epoch`
        # while the replay runs, view_epoch catches up to it before
        # sc_version publication, so any view a version gate certifies
        # is already resident in the stack at a covering epoch.
        self.trad_epoch = 0
        self.view_epoch = 0
        self._trad: dict = {}
        self._sc: dict = {}
        self._queue: "queue.SimpleQueue[Request]" = queue.SimpleQueue()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        if async_mapper:
            self._thread = threading.Thread(
                target=self._loop, daemon=True, name=name)
            self._thread.start()

    # -- version bookkeeping (main-thread side) -----------------------------

    def record(self, keys: Sequence[Hashable]) -> list:
        """Bump ``trad_version`` for ``keys``; **caller must hold
        :attr:`lock`** together with the authoritative mutation.  Returns
        the new versions, to be carried by the maintenance request."""
        out = []
        for k in keys:
            v = self._trad.get(k, 0) + 1
            self._trad[k] = v
            out.append(v)
        # after the client stored its mutated state (callers reassign
        # state first, then record under the same lock): cache readers
        # that see the new epoch are guaranteed to snapshot the new state
        self.trad_epoch += 1
        return out

    def invalidate(self, keys: Sequence[Hashable]) -> None:
        """Mark views stale with no replay planned (e.g. sequence release):
        bumps ``trad_version`` and resets ``sc_version`` to -1.  Caller
        must hold :attr:`lock`."""
        for k in keys:
            self._trad[k] = self._trad.get(k, 0) + 1
            self._sc[k] = -1
        self.trad_epoch += 1

    @property
    def next_view_epoch(self) -> int:
        """The epoch the in-flight replay's publications carry.

        Meaningful only on the replay path (mapper thread or ``pump()``
        caller, under ``_replay_mutex``): replay callables publish their
        operands into the stacked cache at this epoch, and ``_process``
        bumps ``view_epoch`` to exactly it before publishing
        ``sc_version`` — so a reader whose gate certified the new
        version finds the cache entry already at a covering epoch."""
        return self.view_epoch + 1

    def trad_version(self, key: Hashable = GLOBAL_VIEW) -> int:
        return self._trad.get(key, 0)

    def sc_version(self, key: Hashable = GLOBAL_VIEW) -> int:
        return self._sc.get(key, -1)

    def versions(self, key: Hashable = GLOBAL_VIEW) -> tuple:
        return self.trad_version(key), self.sc_version(key)

    def in_sync(self, keys: Optional[Iterable[Hashable]] = None) -> bool:
        if keys is None:
            keys = list(self._trad)
        return all(self.sc_version(k) >= self.trad_version(k) for k in keys)

    # -- request submission --------------------------------------------------

    def submit_update(self, keys: Sequence[Hashable], versions: Sequence[int],
                      payload: Any = None) -> None:
        self._queue.put(Request(UPDATE, dict(zip(keys, versions)), payload))

    def submit_create(self, keys: Sequence[Hashable], versions: Sequence[int],
                      payload: Any = None) -> None:
        """Enqueue a view (re)build.  Pending updates it makes redundant
        are popped as outdated *now* (the paper pops them at enqueue time
        after a directory doubling); the batch-side collapse in
        :meth:`_process` catches any that race past this."""
        req = Request(CREATE, dict(zip(keys, versions)), payload)
        pending = self._drain()
        kept = [r for r in pending if not _subsumed(r, req.versions)]
        self.stats.collapsed += len(pending) - len(kept)
        for r in kept:
            self._queue.put(r)
        self._queue.put(req)

    # -- routing -------------------------------------------------------------

    @property
    def threshold(self):
        """Scalar threshold of the routing policy, or None for policies
        without one (e.g. :class:`HysteresisRouting`)."""
        return getattr(self.routing, "threshold", None)

    @threshold.setter
    def threshold(self, value: float) -> None:
        if not hasattr(self.routing, "threshold"):
            raise AttributeError(
                f"routing policy {type(self.routing).__name__} has no "
                "scalar threshold; set its fields directly")
        self.routing.threshold = float(value)

    def gate(self, metric: float,
             keys: Optional[Iterable[Hashable]] = None) -> bool:
        """Pure decision: version gate AND routing policy."""
        return self.in_sync(keys) and bool(self.routing.decide(metric))

    def count_route(self, used_shortcut: bool) -> None:
        if used_shortcut:
            self.routed_shortcut += 1
        else:
            self.routed_fallback += 1

    # -- mapper side ---------------------------------------------------------

    def pump(self, max_requests: int = 1 << 30) -> int:
        """Synchronously process pending maintenance (mapper surrogate
        for deterministic tests/benchmarks)."""
        done = 0
        while done < max_requests:
            batch = self._drain()
            if not batch:
                break
            with self._replay_mutex:
                self._process(batch)
            done += len(batch)
        return done

    def wait_in_sync(self, keys: Optional[Iterable[Hashable]] = None,
                     timeout: float = 30.0) -> bool:
        """Block until the tracked views caught up (async mode); in sync
        mode this simply pumps."""
        keys = None if keys is None else list(keys)
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self.in_sync(keys) and self._queue.empty():
                return True
            if self._thread is None:
                self.pump()
            else:
                time.sleep(self.poll_interval / 4)
        return self.in_sync(keys)

    def close(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def _drain(self) -> list:
        out = []
        while True:
            try:
                out.append(self._queue.get_nowait())
            except queue.Empty:
                return out

    def _loop(self) -> None:
        """The paper's mapper thread: poll at a fixed frequency, replay."""
        while not self._stop.is_set():
            batch = self._drain()
            if batch:
                with self._replay_mutex:
                    self._process(batch)
            else:
                time.sleep(self.poll_interval)

    def _process(self, batch: list) -> None:
        """Replay one drained batch.

        1. collapse: drop updates whose every view key has a later (or
           equal) create in the batch — the create rebuilds from the
           authoritative structure, which already contains their effect;
        2. replay survivors in FIFO order, handing the client contiguous
           runs of same-kind requests (so e.g. EH merges one update batch
           and the KV cache composes creates before later appends) —
           replay callables publish their operands straight into the
           stacked cache at :attr:`next_view_epoch` (zero-copy publish;
           the lookup path never patches);
        3. eagerly populate the view arrays (§3.1);
        4. bump ``view_epoch`` to the epoch the replays published at,
           then publish ``sc_version`` monotonically.
        """
        with self.lock:
            snap = self._snapshot()

        last_create: dict = {}
        for r in batch:
            if r.kind == CREATE:
                for k, v in r.versions.items():
                    last_create[k] = max(last_create.get(k, -1), v)
        kept = []
        for r in batch:
            if r.kind == UPDATE and _subsumed(r, last_create):
                self.stats.collapsed += 1
                continue
            kept.append(r)

        t0 = time.perf_counter()
        i = 0
        while i < len(kept):
            j = i
            while j < len(kept) and kept[j].kind == kept[i].kind:
                j += 1
            run = kept[i:j]
            if kept[i].kind == CREATE:
                self._replay_create(snap, run)
                self.stats.creates += 1
            else:
                self._replay_update(snap, run)
                self.stats.updates += 1
            i = j
        t1 = time.perf_counter()
        for a in self._view_arrays():
            a.block_until_ready()
        t2 = time.perf_counter()
        self.stats.replay_seconds += t1 - t0
        self.stats.populate_seconds += t2 - t1

        # catch up to next_view_epoch (what the replays published at)
        # BEFORE publishing sc versions: once a gate certifies these
        # versions, the stacked cache already holds the published
        # operands at a covering epoch — a reader can never be handed
        # a stack older than the view the gate certified
        self.view_epoch += 1

        for r in batch:
            for k, v in r.versions.items():
                self._sc[k] = max(self._sc.get(k, -1), v)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def _subsumed(r: Request, create_versions: dict) -> bool:
    """True when every view key of update ``r`` is covered by a create at
    the same or a later version (replaying ``r`` would be redundant)."""
    return bool(r.versions) and all(
        create_versions.get(k, -1) >= v for k, v in r.versions.items())
