"""Runtime package: the shortcut-maintenance runtime plus the train/serve
step factories.

The train/serve exports are resolved lazily (PEP 562): the maintenance
runtime (``repro.runtime.mapper``) is imported by the core index and the
KV cache, and must not drag the full model stack (and its import cost)
into every index user — nor create a cycle through ``repro.kvcache``.
"""
from repro.runtime.mapper import (  # noqa: F401
    GLOBAL_VIEW, FanInRouting, FragmentationRouting, HysteresisRouting,
    MaintenanceStats, Request, ShortcutMapper)
from repro.runtime.shard_group import MapperGroup  # noqa: F401

_LAZY = {
    "TrainStep": ("repro.runtime.train", "TrainStep"),
    "make_train_step": ("repro.runtime.train", "make_train_step"),
    "DecodeState": ("repro.runtime.serve", "DecodeState"),
    "decode_state_specs": ("repro.runtime.serve", "decode_state_specs"),
    "make_prefill_step": ("repro.runtime.serve", "make_prefill_step"),
    "make_serve_step": ("repro.runtime.serve", "make_serve_step"),
}


def __getattr__(name):
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None
    import importlib
    return getattr(importlib.import_module(mod_name), attr)
