from repro.runtime.train import TrainStep, make_train_step  # noqa: F401
from repro.runtime.serve import (  # noqa: F401
    DecodeState, decode_state_specs, make_prefill_step, make_serve_step)
