"""Sharded shortcut runtime: a group of independent mappers (DESIGN.md §4).

The generic runtime (``runtime/mapper.ShortcutMapper``) maintains ONE
shortcut view family.  Production-scale structures partition their key
space into shards — each shard a full structure of its own — exactly to
localize translation state (cf. Utopia's restrictive mappings and
NDPage's per-unit page tables in PAPERS.md): per-shard view size stays
bounded (the VMEM-resident regime of the Pallas kernels, DESIGN.md
§2.4), and maintenance, versioning, and the create-collapses-updates
batching are confined to one shard instead of the whole structure (the
paper's §5 shootdown concern).

:class:`MapperGroup` owns N :class:`~repro.runtime.mapper.ShortcutMapper`
instances with **independent** queues, versions, routing policies, locks
and (in async mode) threads, plus:

  * a **key → shard router** (client-supplied; Sharded-EH routes on the
    top bits of the directory hash, the KV manager on ``seq_id % N``);
  * **aggregated** :class:`~repro.runtime.mapper.MaintenanceStats` and
    route counters across the group (per-shard stats remain available
    through each member);
  * group-wide ``pump()`` / ``wait_in_sync()`` / ``close()`` and the
    sharded version gate :meth:`in_sync` / :meth:`gate`, keyed by
    ``{shard: view keys}`` so a read only waits on the shards it
    actually touches.

The group deliberately does NOT share any state between members: one
shard's create request can never collapse, gate, or serialize behind
another shard's updates — that independence is the point, and
``tests/test_sharded_eh.py`` pins it.
"""
from __future__ import annotations

import time
from dataclasses import fields
from typing import Callable, Dict, Hashable, Iterable, Optional, Sequence

from repro.runtime.mapper import MaintenanceStats, ShortcutMapper

#: ``{shard index: view keys}`` — the sharded analogue of the key lists
#: the flat runtime takes; ``None`` values mean "all keys of that shard".
KeysByShard = Dict[int, Optional[Iterable[Hashable]]]


class MapperGroup:
    """N independent shortcut mappers + a router, presented as one unit.

    Parameters
    ----------
    mappers:
        the member :class:`ShortcutMapper` instances, one per shard, in
        shard order.  The group takes ownership (``close()`` closes all).
    router:
        ``f(key) -> shard index`` for single keys.  Optional — clients
        that bucketize batches themselves (Sharded-EH hashes whole numpy
        arrays at once) may never call it; :meth:`route` raises if it is
        needed but absent.
    """

    def __init__(self, mappers: Sequence[ShortcutMapper], *,
                 router: Optional[Callable[[Hashable], int]] = None):
        if not mappers:
            raise ValueError("MapperGroup needs at least one mapper")
        self.mappers = list(mappers)
        self._router = router

    # -- container protocol --------------------------------------------------

    def __len__(self) -> int:
        return len(self.mappers)

    def __getitem__(self, shard: int) -> ShortcutMapper:
        return self.mappers[shard]

    def __iter__(self):
        return iter(self.mappers)

    # -- routing -------------------------------------------------------------

    def route(self, key: Hashable) -> int:
        """Shard index owning ``key`` (via the client's router)."""
        if self._router is None:
            raise ValueError("MapperGroup was built without a router")
        shard = int(self._router(key))
        if not 0 <= shard < len(self.mappers):
            raise IndexError(f"router sent key {key!r} to shard {shard} "
                             f"of {len(self.mappers)}")
        return shard

    def mapper_for(self, key: Hashable) -> ShortcutMapper:
        return self.mappers[self.route(key)]

    # -- aggregated bookkeeping ----------------------------------------------

    @property
    def stats(self) -> MaintenanceStats:
        """Sum of all members' stats (a fresh snapshot object; mutate the
        per-shard ``group[i].stats`` instances, never this one)."""
        agg = MaintenanceStats()
        for m in self.mappers:
            for f in fields(MaintenanceStats):
                setattr(agg, f.name,
                        getattr(agg, f.name) + getattr(m.stats, f.name))
        return agg

    def per_shard_stats(self) -> list:
        return [m.stats for m in self.mappers]

    @property
    def routed_shortcut(self) -> int:
        return sum(m.routed_shortcut for m in self.mappers)

    @property
    def routed_fallback(self) -> int:
        return sum(m.routed_fallback for m in self.mappers)

    def count_route(self, used_shortcut: bool, shard: int = 0) -> None:
        """Count one routed batch, attributed to ``shard`` (batch-level
        decisions are one event, not one per touched shard)."""
        self.mappers[shard].count_route(used_shortcut)

    # -- sharded version gate ------------------------------------------------

    def in_sync(self, keys_by_shard: Optional[KeysByShard] = None) -> bool:
        """True when every involved shard's views are caught up.

        ``keys_by_shard=None`` checks all keys of all shards; a dict
        restricts the gate to the listed shards (and, per shard, to the
        listed keys) — the sharded read set."""
        if keys_by_shard is None:
            return all(m.in_sync() for m in self.mappers)
        return all(self.mappers[s].in_sync(keys)
                   for s, keys in keys_by_shard.items())

    def gate(self, metric: float,
             keys_by_shard: Optional[KeysByShard] = None) -> bool:
        """Version gate across the involved shards AND every involved
        shard's routing policy accepting ``metric``.  Policies are
        per-shard (independent thresholds / hysteresis state); a batch
        routes the shortcut only when all of them agree.  Distinct
        policy *objects* each decide exactly once, without
        short-circuiting — a policy shared across shards (one object,
        many members) must see one state transition per gate, not one
        per shard it happens to back."""
        shards = (range(len(self.mappers)) if keys_by_shard is None
                  else sorted(keys_by_shard))
        if not self.in_sync(keys_by_shard):
            return False
        policies, seen = [], set()
        for s in shards:
            p = self.mappers[s].routing
            if id(p) not in seen:
                seen.add(id(p))
                policies.append(p)
        decisions = [bool(p.decide(metric)) for p in policies]
        return all(decisions)

    # -- group-wide maintenance ----------------------------------------------

    def pump(self, max_requests: int = 1 << 30) -> int:
        """Synchronously drain every shard's queue (mapper surrogate)."""
        return sum(m.pump(max_requests) for m in self.mappers)

    def wait_in_sync(self, keys_by_shard: Optional[KeysByShard] = None,
                     timeout: float = 30.0) -> bool:
        """Block until the involved shards caught up; one shared deadline
        across the group (not ``timeout`` per shard)."""
        deadline = time.monotonic() + timeout
        shards = (range(len(self.mappers)) if keys_by_shard is None
                  else sorted(keys_by_shard))
        ok = True
        for s in shards:
            keys = None if keys_by_shard is None else keys_by_shard[s]
            left = deadline - time.monotonic()
            ok &= self.mappers[s].wait_in_sync(keys, max(0.0, left))
        return ok

    def close(self) -> None:
        for m in self.mappers:
            m.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
