"""Sharded shortcut runtime: a group of independent mappers (DESIGN.md §4).

The generic runtime (``runtime/mapper.ShortcutMapper``) maintains ONE
shortcut view family.  Production-scale structures partition their key
space into shards — each shard a full structure of its own — exactly to
localize translation state (cf. Utopia's restrictive mappings and
NDPage's per-unit page tables in PAPERS.md): per-shard view size stays
bounded (the VMEM-resident regime of the Pallas kernels, DESIGN.md
§2.4), and maintenance, versioning, and the create-collapses-updates
batching are confined to one shard instead of the whole structure (the
paper's §5 shootdown concern).

:class:`MapperGroup` owns N :class:`~repro.runtime.mapper.ShortcutMapper`
instances with **independent** queues, versions, routing policies, locks
and (in async mode) threads, plus:

  * a **key → shard router** (client-supplied; Sharded-EH routes on the
    top bits of the directory hash, the KV manager on ``seq_id % N``);
  * an optional :class:`ShardViewRegistry` — per-shard atomically-swapped
    view tuples, so replay callables and ``view_arrays`` read the
    registry instead of closing over whole-structure client attributes;
  * **aggregated** :class:`~repro.runtime.mapper.MaintenanceStats` and
    route counters across the group (per-shard stats remain available
    through each member); batch-level route decisions that span shards
    land on a **group-level** counter instead of being misattributed to
    one shard;
  * group-wide ``pump()`` / ``wait_in_sync()`` / ``close()`` and the
    sharded version gate :meth:`in_sync` / :meth:`gate`, keyed by
    ``{shard: view keys}`` so a read only waits on the shards it
    actually touches.

The group deliberately does NOT share any state between members: one
shard's create request can never collapse, gate, or serialize behind
another shard's updates — that independence is the point, and
``tests/test_sharded_eh.py`` pins it.

This module also owns the generic **cross-shard batching** helpers every
sharded client shares (:func:`shard_order`, :func:`partition_by_shard`,
:func:`pad_batch`): one stable argsort pass bucketizes a batch per
shard, pads each shard's sub-batch to a static capacity drawn from a
bounded size set (bounded set ⇒ bounded jit variants), and the returned
permutation scatters per-shard results back to input order.  Sharded-EH
uses them for its fused lookup; the KV manager for its cross-shard
``get_context``.
"""
from __future__ import annotations

import time
from dataclasses import fields
from typing import (Callable, Dict, Hashable, Iterable, List, Optional,
                    Sequence)

import numpy as np

from repro.runtime.mapper import MaintenanceStats, ShortcutMapper

#: ``{shard index: view keys}`` — the sharded analogue of the key lists
#: the flat runtime takes; ``None`` values mean "all keys of that shard".
KeysByShard = Dict[int, Optional[Iterable[Hashable]]]

#: Static per-shard batch capacities (bounded set => bounded number of
#: jit/pallas variants), mirroring ``shortcut_eh._CHUNK_SIZES``.
_BATCH_SIZES = (64, 256, 1024, 4096, 16384, 65536, 262144)


def pad_batch(n: int) -> int:
    """Smallest static capacity from :data:`_BATCH_SIZES` holding ``n``
    (multiples of the largest beyond it)."""
    for c in _BATCH_SIZES:
        if n <= c:
            return c
    return -(-n // _BATCH_SIZES[-1]) * _BATCH_SIZES[-1]


def shard_order(sid: np.ndarray, num_shards: int):
    """The one stable argsort pass every batched operation shares:
    returns ``(order, counts, starts)`` — shard-sort permutation,
    per-shard key counts, and each shard's offset in the sorted order."""
    order = np.argsort(sid, kind="stable")
    counts = np.bincount(sid, minlength=num_shards)
    starts = np.zeros(num_shards, np.int64)
    np.cumsum(counts[:-1], out=starts[1:])
    return order, counts, starts


def partition_by_shard(keys: np.ndarray, sid: np.ndarray, num_shards: int,
                       cap: int, fill: int = 0, *, order=None, counts=None,
                       starts=None):
    """Bucketize ``keys`` per shard (via :func:`shard_order`, reused when
    the caller already ran it to size ``cap``).

    Returns ``(padded, counts, order, rank)``: ``padded`` is
    (num_shards, cap) with shard s's keys in ``padded[s, :counts[s]]``
    and ``fill`` elsewhere; ``order``/``rank`` invert the permutation —
    input element ``order[i]`` sits at ``padded[sid[order][i],
    rank[i]]``, so per-shard results scatter back to input order with
    ``out[order] = results[sid[order], rank]``.
    """
    keys = np.asarray(keys)
    if order is None or counts is None or starts is None:
        order, counts, starts = shard_order(sid, num_shards)
    sid_sorted = sid[order]
    rank = np.arange(keys.size, dtype=np.int64) - starts[sid_sorted]
    padded = np.full((num_shards, cap), fill, keys.dtype)
    padded[sid_sorted, rank] = keys[order]
    return padded, counts, order, rank


class ShardViewRegistry:
    """Per-shard, atomically-published shortcut view tuples.

    Two storage modes behind one API:

    **Standalone** (``cache=None``): each slot holds ONE tuple of device
    arrays (or ``None`` before the first publication).  :meth:`publish`
    is a single list-item store and :meth:`snapshot` a single list-item
    load — both atomic under the GIL — so a reader can never pair
    arrays from two different publications of the same shard (the tear
    the KV manager's old two-attribute ``view_k, view_v = ...``
    publication allowed).

    **Cache-backed** (``cache=`` a
    :class:`~repro.runtime.operand_cache.StackedOperandCache`): the
    registry stops owning any arrays and becomes a per-shard facade of
    one stacked operand family — :meth:`publish` writes the shard's
    slice straight into the stack at the caller-supplied client epoch
    (zero-copy publish, DESIGN.md §4.4) and :meth:`snapshot` returns
    the cache's memoized slice of it.  Tear-freedom carries over: a
    slice tuple is drawn from ONE atomically-swapped stacked tuple.

    Writer discipline (both modes): one writer per slot — the shard's
    mapper thread (or the ``pump()`` caller in sync mode), enforced by
    the mapper's per-shard replay mutex
    (``ShortcutMapper._replay_mutex``).  That single-writer rule + the
    atomic swap is exactly the ``ShortcutEH._view`` protocol, lifted to
    N shards; no cross-shard lock exists and none is needed.
    """

    def __init__(self, num_shards: int, *, cache=None,
                 family: str = "kv_view"):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        self._n = num_shards
        self._cache = cache
        self._family = family
        if cache is None:
            self._views: List[Optional[tuple]] = [None] * num_shards
            # publish epochs for the device-resident operand cache
            # (runtime/operand_cache.py): bumped AFTER the tuple store,
            # so a reader that reads the epoch first and snapshots
            # second can at worst record a newer tuple under an older
            # epoch — a redundant refresh next get(), never stale
            self._epochs: List[int] = [0] * num_shards
        elif cache.num_shards != num_shards:
            raise ValueError(f"cache has {cache.num_shards} shards, "
                             f"registry asked for {num_shards}")

    def __len__(self) -> int:
        return self._n

    def publish(self, shard: int, arrays: Iterable, *,
                epoch: Optional[int] = None) -> None:
        """Publish shard ``shard``'s view tuple.

        Standalone: atomic tuple swap, then bump the internal epoch
        (writer order matters, see ``_epochs``); ``epoch`` is ignored.
        Cache-backed: one donated ``dynamic_update_slice`` into the
        stacked family at the client ``epoch`` (required — replays pass
        their mapper's ``next_view_epoch``)."""
        if self._cache is not None:
            if epoch is None:
                raise ValueError("cache-backed registry publications "
                                 "must carry the client epoch")
            self._cache.publish(self._family, shard, tuple(arrays),
                                epoch=epoch)
            return
        self._views[shard] = tuple(arrays)
        self._epochs[shard] += 1

    def epoch(self, shard: int) -> int:
        """Shard's publish epoch; read BEFORE :meth:`snapshot`."""
        return self.epochs()[shard]

    def epochs(self) -> List[int]:
        """All shards' publish epochs (copied; read before snapshots)."""
        if self._cache is not None:
            eps = self._cache.epochs(self._family)
            return [0] * self._n if eps is None else eps
        return list(self._epochs)

    def snapshot(self, shard: int) -> Optional[tuple]:
        """One consistent view tuple (or None) — read the slot ONCE and
        index the result; never re-read per array.  Cache-backed: the
        memoized slice of the stack (zero device work in steady state)."""
        if self._cache is not None:
            return self._cache.slice_of(self._family, shard)
        return self._views[shard]

    def snapshot_all(self) -> list:
        """Per-shard snapshots, each internally consistent (the list is
        copied so concurrent publications don't mutate it underfoot)."""
        return [self.snapshot(s) for s in range(self._n)]

    def arrays(self, shard: int) -> tuple:
        """Population target for the runtime's ``view_arrays`` hook:
        the shard's current arrays, or () before first publication.
        Cache-backed: the stacked family itself — it IS the published
        object the reader will be handed."""
        if self._cache is not None:
            return self._cache.handle(self._family) or ()
        v = self._views[shard]
        return () if v is None else v


class MapperGroup:
    """N independent shortcut mappers + a router, presented as one unit.

    Parameters
    ----------
    mappers:
        the member :class:`ShortcutMapper` instances, one per shard, in
        shard order.  The group takes ownership (``close()`` closes all).
    router:
        ``f(key) -> shard index`` for single keys.  Optional — clients
        that bucketize batches themselves (Sharded-EH hashes whole numpy
        arrays at once) may never call it; :meth:`route` raises if it is
        needed but absent.
    views:
        optional :class:`ShardViewRegistry` the members' replay
        callables publish into; exposing it here lets group consumers
        (serving loops, benchmarks) snapshot per-shard views without
        reaching into the client object.
    """

    def __init__(self, mappers: Sequence[ShortcutMapper], *,
                 router: Optional[Callable[[Hashable], int]] = None,
                 views: Optional[ShardViewRegistry] = None):
        if not mappers:
            raise ValueError("MapperGroup needs at least one mapper")
        if views is not None and len(views) != len(mappers):
            raise ValueError(
                f"view registry has {len(views)} slots for "
                f"{len(mappers)} mappers")
        self.mappers = list(mappers)
        self._router = router
        self.views = views
        # batch-level decisions spanning shards (shard=None in
        # count_route) land here, not on an arbitrary member
        self._routed_shortcut_group = 0
        self._routed_fallback_group = 0

    # -- container protocol --------------------------------------------------

    def __len__(self) -> int:
        return len(self.mappers)

    def __getitem__(self, shard: int) -> ShortcutMapper:
        return self.mappers[shard]

    def __iter__(self):
        return iter(self.mappers)

    # -- routing -------------------------------------------------------------

    def route(self, key: Hashable) -> int:
        """Shard index owning ``key`` (via the client's router)."""
        if self._router is None:
            raise ValueError("MapperGroup was built without a router")
        shard = int(self._router(key))
        if not 0 <= shard < len(self.mappers):
            raise IndexError(f"router sent key {key!r} to shard {shard} "
                             f"of {len(self.mappers)}")
        return shard

    def mapper_for(self, key: Hashable) -> ShortcutMapper:
        return self.mappers[self.route(key)]

    # -- aggregated bookkeeping ----------------------------------------------

    @property
    def stats(self) -> MaintenanceStats:
        """Sum of all members' stats (a fresh snapshot object; mutate the
        per-shard ``group[i].stats`` instances, never this one)."""
        agg = MaintenanceStats()
        for m in self.mappers:
            for f in fields(MaintenanceStats):
                setattr(agg, f.name,
                        getattr(agg, f.name) + getattr(m.stats, f.name))
        return agg

    def per_shard_stats(self) -> list:
        return [m.stats for m in self.mappers]

    @property
    def routed_shortcut(self) -> int:
        return self._routed_shortcut_group + \
            sum(m.routed_shortcut for m in self.mappers)

    @property
    def routed_fallback(self) -> int:
        return self._routed_fallback_group + \
            sum(m.routed_fallback for m in self.mappers)

    def count_route(self, used_shortcut: bool,
                    shard: Optional[int] = None) -> None:
        """Count one routed batch: attributed to ``shard`` when the
        decision belongs to a single shard, otherwise (``shard=None``)
        to the group-level counter.  Batch-level decisions are one event
        — never one per touched shard, and never silently credited to
        shard 0 (that skewed per-shard stats for multi-shard batches)."""
        if shard is None:
            if used_shortcut:
                self._routed_shortcut_group += 1
            else:
                self._routed_fallback_group += 1
        else:
            self.mappers[shard].count_route(used_shortcut)

    # -- sharded version gate ------------------------------------------------

    def in_sync(self, keys_by_shard: Optional[KeysByShard] = None) -> bool:
        """True when every involved shard's views are caught up.

        ``keys_by_shard=None`` checks all keys of all shards; a dict
        restricts the gate to the listed shards (and, per shard, to the
        listed keys) — the sharded read set."""
        if keys_by_shard is None:
            return all(m.in_sync() for m in self.mappers)
        return all(self.mappers[s].in_sync(keys)
                   for s, keys in keys_by_shard.items())

    def gate(self, metric: float,
             keys_by_shard: Optional[KeysByShard] = None) -> bool:
        """Version gate across the involved shards AND every involved
        shard's routing policy accepting ``metric``.  Policies are
        per-shard (independent thresholds / hysteresis state); a batch
        routes the shortcut only when all of them agree.  Distinct
        policy *objects* each decide exactly once, without
        short-circuiting — a policy shared across shards (one object,
        many members) must see one state transition per gate, not one
        per shard it happens to back."""
        shards = (range(len(self.mappers)) if keys_by_shard is None
                  else sorted(keys_by_shard))
        if not self.in_sync(keys_by_shard):
            return False
        policies, seen = [], set()
        for s in shards:
            p = self.mappers[s].routing
            if id(p) not in seen:
                seen.add(id(p))
                policies.append(p)
        decisions = [bool(p.decide(metric)) for p in policies]
        return all(decisions)

    # -- group-wide maintenance ----------------------------------------------

    def pump(self, max_requests: int = 1 << 30) -> int:
        """Synchronously drain every shard's queue (mapper surrogate)."""
        return sum(m.pump(max_requests) for m in self.mappers)

    def wait_in_sync(self, keys_by_shard: Optional[KeysByShard] = None,
                     timeout: float = 30.0) -> bool:
        """Block until the involved shards caught up; one shared deadline
        across the group (not ``timeout`` per shard)."""
        deadline = time.monotonic() + timeout
        shards = (range(len(self.mappers)) if keys_by_shard is None
                  else sorted(keys_by_shard))
        ok = True
        for s in shards:
            keys = None if keys_by_shard is None else keys_by_shard[s]
            left = deadline - time.monotonic()
            ok &= self.mappers[s].wait_in_sync(keys, max(0.0, left))
        return ok

    def close(self) -> None:
        for m in self.mappers:
            m.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
