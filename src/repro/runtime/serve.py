"""Serving runtime: prefill + decode steps over the shortcut or paged cache.

Two jit-able decode paths, mirroring the paper's two access paths:

  * **shortcut** (:func:`make_serve_step`) — decode over the contiguous
    per-sequence view ``(L, B, S_cap, KV, hd)``: token positions are address
    arithmetic, zero data-dependent gathers.  This is the paper's shortcut
    directory applied to KV serving, and the default dry-run `serve_step`.
  * **paged** (:func:`make_paged_serve_step`) — decode through the block
    table: a dependent gather (table load -> block gather) materializes the
    context first.  This is the "traditional directory" baseline the
    roofline comparison measures against.

State layout is one NamedTuple so the launcher can derive shardings from
logical names (``decode_state_names``) and jit with donated buffers.

**Per-shard decode states** (:func:`shard_decode_state` /
:func:`merge_decode_states`): sequence row ``b`` is owned by shard
``b % num_shards`` — the same partition ``ShortcutKVManager`` uses for
its per-shard view tensors (DESIGN.md §4.2) — so each shard's decode
loop steps a state whose view arrays it alone owns.  N loops run
lock-free side by side (no shared tensors, no view lock) and
``merge_decode_states`` interleaves the rows back whenever a
whole-batch state is needed.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.models.ssm import SSMCache
from repro.kvcache import paged_cache as pc


class DecodeState(NamedTuple):
    """Decode-time state.  Unused members are () (e.g. no view_k for pure
    SSM archs, no ssm_* for pure attention)."""
    view_k: Any          # (L, B, S_cap, KV, hd) or ()
    view_v: Any
    ssm_conv: Any        # (L, B, d_conv-1, conv_dim) or ()
    ssm_state: Any       # (L, B, H, P, N) or ()
    ctx_len: jax.Array   # (B,) tokens already materialized in the cache


def decode_state_struct(cfg: ArchConfig, batch: int, s_cap: int,
                        dtype=jnp.bfloat16) -> DecodeState:
    """ShapeDtypeStruct stand-ins (dry-run contract)."""
    L, B = cfg.num_layers, batch
    vk = vv = ()
    sc = ss = ()
    if cfg.has_attention:
        # attention-native layout: kv-head-major, positions contiguous —
        # the score/pv einsums consume it without per-layer transposes
        # (measured: layout copies were ~40% of decode HBM traffic)
        shape = (L, B, cfg.num_kv_heads, s_cap, cfg.resolved_head_dim)
        vk = jax.ShapeDtypeStruct(shape, dtype)
        vv = jax.ShapeDtypeStruct(shape, dtype)
    if cfg.has_ssm:
        conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_state
        sc = jax.ShapeDtypeStruct((L, B, cfg.ssm_conv - 1, conv_dim), dtype)
        ss = jax.ShapeDtypeStruct(
            (L, B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32)
    return DecodeState(view_k=vk, view_v=vv, ssm_conv=sc, ssm_state=ss,
                       ctx_len=jax.ShapeDtypeStruct((B,), jnp.int32))


def decode_state_names(cfg: ArchConfig) -> DecodeState:
    """Logical dim names parallel to :func:`decode_state_struct`."""
    vk = vv = ()
    sc = ss = ()
    if cfg.has_attention:
        vk = vv = ["layer", "batch", "kv_heads", "ctx", "head_dim"]
    if cfg.has_ssm:
        sc = ["layer", "batch", None, "ssm_inner"]
        ss = ["layer", "batch", "ssm_heads", None, None]
    return DecodeState(view_k=vk, view_v=vv, ssm_conv=sc, ssm_state=ss,
                       ctx_len=["batch"])


def decode_state_specs(cfg: ArchConfig, struct: DecodeState, mesh,
                       rules=None) -> DecodeState:
    """NamedSharding pytree for a decode-state struct on ``mesh``."""
    from jax.sharding import NamedSharding
    from repro.distributed.sharding import logical_spec
    names = decode_state_names(cfg)

    def one(s, n):
        if s == () or n == ():
            return ()
        return NamedSharding(mesh, logical_spec(s.shape, n, mesh, rules))

    return DecodeState(*[one(s, n) for s, n in zip(struct, names)])


def decode_state_init(cfg: ArchConfig, batch: int, s_cap: int,
                      dtype=jnp.bfloat16) -> DecodeState:
    """Zero-initialized real state (used by examples/tests)."""
    struct = decode_state_struct(cfg, batch, s_cap, dtype)
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), struct,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# ---------------------------------------------------------------------------
# Per-shard decode states (the serving twin of the per-shard KV views).
# ---------------------------------------------------------------------------

def _take_rows(x, sl, axis: int):
    """Slice the batch axis of a state member ((), ctx_len axis 0,
    tensors axis 1)."""
    if isinstance(x, tuple):     # the () placeholder of unused members
        return ()
    ix = (slice(None),) * axis + (sl,)
    return x[ix]


def shard_decode_state(state: DecodeState,
                       num_shards: int) -> "list[DecodeState]":
    """Split a whole-batch decode state into ``num_shards`` states;
    shard ``s`` owns sequence rows ``s, s + N, s + 2N, ...`` — exactly
    ``ShortcutKVManager``'s ``seq_id % N`` partition, so a serving stack
    can pair each shard's decode loop with its shard's view registry
    slot.  Every member keeps the whole-batch layout minus the foreign
    rows; the per-shard states drive the unchanged :func:`make_serve_step`.
    """
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    return [DecodeState(
        view_k=_take_rows(state.view_k, slice(s, None, num_shards), 1),
        view_v=_take_rows(state.view_v, slice(s, None, num_shards), 1),
        ssm_conv=_take_rows(state.ssm_conv, slice(s, None, num_shards), 1),
        ssm_state=_take_rows(state.ssm_state, slice(s, None, num_shards), 1),
        ctx_len=state.ctx_len[s::num_shards])
        for s in range(num_shards)]


def merge_decode_states(states: "Sequence[DecodeState]") -> DecodeState:
    """Inverse of :func:`shard_decode_state`: interleave per-shard rows
    back into one whole-batch state (row ``b`` from shard ``b % N``)."""
    num_shards = len(states)
    if num_shards == 1:
        return states[0]
    sizes = [int(st.ctx_len.shape[0]) for st in states]
    total = sum(sizes)
    # global row of each concatenated element, then its inverse gather
    order = np.concatenate([np.arange(s, total, num_shards)
                            for s in range(num_shards)])
    inv = np.empty(total, np.int64)
    inv[order] = np.arange(total)
    inv = jnp.asarray(inv)

    def merge(parts, axis):
        if isinstance(parts[0], tuple):   # () placeholder
            return ()
        return jnp.take(jnp.concatenate(list(parts), axis=axis), inv,
                        axis=axis)

    return DecodeState(
        view_k=merge([st.view_k for st in states], 1),
        view_v=merge([st.view_v for st in states], 1),
        ssm_conv=merge([st.ssm_conv for st in states], 1),
        ssm_state=merge([st.ssm_state for st in states], 1),
        ctx_len=merge([st.ctx_len for st in states], 0))


# ---------------------------------------------------------------------------
# Prefill.
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ArchConfig, s_cap: int,
                      dtype=jnp.bfloat16) -> Callable:
    """(params, batch) -> (last-pos logits, DecodeState).

    Runs the full forward once, then linearizes the per-layer caches into
    the S_cap-padded shortcut view (the *create request* of the serving
    layer, executed eagerly because prefill is itself off the decode path).
    """
    def prefill(params, batch):
        logits, caches = M.prefill_forward(params, cfg, batch)
        lead = batch.get("tokens", batch.get("embeddings"))
        B = lead.shape[0]
        vk = vv = ()
        sc = ss = ()
        S = 0
        if cfg.has_attention:
            k, v = caches.k, caches.v          # (L, B, S, KV, hd)
            L, _, S = k.shape[:3]
            pad = s_cap - S
            # (L,B,S,KV,hd) -> attention-native (L,B,KV,S,hd), padded
            vk = jnp.pad(k.astype(dtype).transpose(0, 1, 3, 2, 4),
                         ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
            vv = jnp.pad(v.astype(dtype).transpose(0, 1, 3, 2, 4),
                         ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        if cfg.has_ssm:
            sc = caches.ssm.conv.astype(dtype)  # (L, B, dc-1, conv_dim)
            ss = caches.ssm.state               # (L, B, H, P, N) f32
            if S == 0:
                S = lead.shape[1]
        if cfg.input_mode == "prefix_embeddings":
            S = lead.shape[1] + cfg.prefix_len if not cfg.has_attention else S
        ctx_len = jnp.full((B,), S, jnp.int32)
        return logits, DecodeState(view_k=vk, view_v=vv, ssm_conv=sc,
                                   ssm_state=ss, ctx_len=ctx_len)
    return prefill


def _write_row(view, idx, new):
    """view (L,B,KV,S,hd) <- new (L,B,KV,hd) at per-batch position idx
    (broadcast (1,B,1,1,1)) along the S axis."""
    L, B, KV, S, hd = view.shape
    pos = jnp.broadcast_to(idx, (L, B, KV, 1, hd))
    return jnp.put_along_axis(view, pos, new[:, :, :, None], axis=3,
                              inplace=False)


# ---------------------------------------------------------------------------
# Decode: shortcut path.
# ---------------------------------------------------------------------------

def make_serve_step(cfg: ArchConfig) -> Callable:
    """(params, state, token (B,)) -> (next_token (B,), new state).

    The shortcut decode: attention reads the contiguous view; the new
    token's KV is scattered into position ctx_len (one row per sequence) —
    the *update request* replay, fused into the step.
    """
    def serve_step(params, state: DecodeState, token: jax.Array):
        B = token.shape[0]
        ssm_ctx = SSMCache(conv=state.ssm_conv, state=state.ssm_state) \
            if cfg.has_ssm else ()
        ctx = M.LayerCache(k=state.view_k, v=state.view_v, ssm=ssm_ctx)
        ctx_len_inc = state.ctx_len + 1          # includes current token
        logits, new = M.decode_step(params, cfg, token, ctx, ctx_len_inc)
        vk, vv = state.view_k, state.view_v
        if cfg.has_attention:
            # along-axis row write: one index dim (position within S),
            # everything else batched — stays a windowed in-place update
            # instead of the full-cache f32 transpose XLA emits for a
            # generic 2-D-index scatter
            idx = state.ctx_len[None, :, None, None, None]
            vk = _write_row(vk, idx, new.k.astype(vk.dtype))
            vv = _write_row(vv, idx, new.v.astype(vv.dtype))
        sc, ss = state.ssm_conv, state.ssm_state
        if cfg.has_ssm:
            sc, ss = new.ssm.conv.astype(jnp.asarray(sc).dtype), new.ssm.state
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, DecodeState(view_k=vk, view_v=vv, ssm_conv=sc,
                                       ssm_state=ss, ctx_len=ctx_len_inc)
    return serve_step


# ---------------------------------------------------------------------------
# Decode: paged (traditional) path — the roofline baseline.
# ---------------------------------------------------------------------------

def make_paged_serve_step(cfg: ArchConfig) -> Callable:
    """(params, cache: PagedKVCache, token, seq_ids) ->
    (next_token, cache).  Context is materialized through the block-table
    indirection every step (two dependent gathers), then attention runs over
    the gathered copy — the cost the shortcut eliminates."""
    def serve_step(params, cache: pc.PagedKVCache, token: jax.Array,
                   seq_ids: jax.Array):
        k_ctx, v_ctx = pc.gather_context(cache, seq_ids)
        ctx = M.LayerCache(k=k_ctx, v=v_ctx, ssm=())
        ctx_len_inc = cache.seq_lens[seq_ids] + 1
        logits, new = M.decode_step(params, cfg, token, ctx, ctx_len_inc)
        cache = pc.append_tokens(cache, seq_ids, new.k, new.v)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, cache
    return serve_step
