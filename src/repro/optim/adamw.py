"""AdamW with optionally-factored second moment (Adafactor-style) for the
very large architectures, plus global-norm clipping.

States mirror the parameter pytree, so ``distributed.param_specs`` shards
them identically to the weights (ZeRO: optimizer state lives on the same
shards as its parameter slice — no extra collectives at update time).

``factored=True`` stores row/col second-moment statistics for >=2-D params
(memory: O(n+m) instead of O(n*m)), which is what lets the 104B/480B configs
fit optimizer state in HBM at 256 chips; see EXPERIMENTS.md §Dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any              # first moment, param-shaped (param dtype f32)
    v: Any              # second moment: param-shaped OR (row, col) tuple
    # factored entries are dicts {"vr": ..., "vc": ...}


def _is_factored_leaf(p: jax.Array, factored: bool) -> bool:
    return factored and p.ndim >= 2 and p.shape[-1] >= 128 \
        and p.shape[-2] >= 128


def adamw_init(params, factored: bool = False) -> AdamWState:
    def v_init(p):
        if _is_factored_leaf(p, factored):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return jnp.zeros(p.shape, jnp.float32)

    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        v=jax.tree.map(v_init, params),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)))


def adamw_update(grads, state: AdamWState, params, *,
                 lr: jax.Array, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 clip_norm: Optional[float] = 1.0, factored: bool = False):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.where(
        (clip_norm is not None) & (gnorm > (clip_norm or 1.0)),
        (clip_norm or 1.0) / jnp.maximum(gnorm, 1e-12), 1.0)
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        if isinstance(v, dict):  # factored second moment
            g2 = jnp.square(g) + 1e-30
            vr = b2 * v["vr"] + (1 - b2) * g2.mean(axis=-1)
            vc = b2 * v["vc"] + (1 - b2) * g2.mean(axis=-2)
            # rank-1 reconstruction v ~= vr vc / mean(vr)
            denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), 1e-30)
            vhat = (vr[..., None] * vc[..., None, :]
                    / denom[..., None]) / bc2
            v_new: Any = {"vr": vr, "vc": vc}
        else:
            v = b2 * v + (1 - b2) * jnp.square(g)
            vhat = v / bc2
            v_new = v
        update = (m / bc1) / (jnp.sqrt(vhat) + eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (update + weight_decay * p32)
        return p_new.astype(p.dtype), m, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "clip_scale": scale}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
