"""Int8 gradient compression with error feedback for DP all-reduces.

Large-scale trick: the data-parallel gradient all-reduce moves
``bytes(params)`` per step per axis; quantizing to int8 with a per-block
scale cuts that ~4x (bf16 -> int8 + amortized scales).  Error feedback (EF)
keeps the *quantization residual* locally and re-adds it next step, which
restores convergence to unquantized SGD/Adam rates.

Usage inside a shard_map'd train step::

    g_q, scales, err = compress_int8(g, err)
    g_sum = jax.lax.psum(g_q.astype(jnp.float32) * scales, "data")

``compressed_psum`` bundles the quantize -> psum -> dequantize round trip.
(The quantize-then-sum is exact w.r.t. what was transmitted: summing the
dequantized int8 values is associative.)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

_BLOCK = 256  # elements per scale block


def _blocked(x: jax.Array):
    n = x.size
    pad = (-n) % _BLOCK
    xf = jnp.pad(x.reshape(-1), (0, pad))
    return xf.reshape(-1, _BLOCK), n, pad


def compress_int8(g: jax.Array, err: Optional[jax.Array] = None):
    """Quantize ``g (+ err)`` to int8 blocks. Returns (q, scales, new_err).

    q: int8 (nblocks, BLOCK); scales: f32 (nblocks, 1); new_err has g's
    shape — the residual to feed back next step."""
    g32 = g.astype(jnp.float32)
    if err is not None:
        g32 = g32 + err
    blocks, n, pad = _blocked(g32)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    resid = (blocks - deq).reshape(-1)
    resid = resid[:n].reshape(g.shape) if pad else resid.reshape(g.shape)
    return q, scale, resid


def decompress_int8(q: jax.Array, scale: jax.Array, shape, dtype):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compressed_psum(g: jax.Array, axis: str,
                    err: Optional[jax.Array] = None):
    """Error-feedback int8 all-reduce of one gradient leaf over ``axis``.

    Returns (g_reduced f32 mean, new_err).  Must run inside shard_map."""
    q, scale, new_err = compress_int8(g, err)
    # transmit int8 payload + f32 scales; psum the *dequantized* blocks so
    # the wire format stays a standard all-reduce (XLA has no int8 AR with
    # per-block scales; the cost model in benchmarks counts q+scale bytes).
    deq = q.astype(jnp.float32) * scale
    total = jax.lax.psum(deq, axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    red = (total / n).reshape(-1)[:g.size].reshape(g.shape)
    return red, new_err
