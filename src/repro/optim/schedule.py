"""Learning-rate schedules (pure functions of the step scalar)."""
from __future__ import annotations

import jax.numpy as jnp


def wsd_schedule(step, *, peak_lr: float, warmup_steps: int,
                 total_steps: int, decay_frac: float = 0.2,
                 floor: float = 0.1):
    """Warmup-Stable-Decay: linear warmup, flat plateau, linear decay to
    ``floor * peak`` over the last ``decay_frac`` of training."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(warmup_steps, 1)
    decay_steps = max(int(total_steps * decay_frac), 1)
    decay_start = total_steps - decay_steps
    decay = 1.0 - (1.0 - floor) * jnp.clip(
        (step - decay_start) / decay_steps, 0.0, 1.0)
    return peak_lr * jnp.minimum(jnp.minimum(warm, 1.0), decay)


def cosine_schedule(step, *, peak_lr: float, warmup_steps: int,
                    total_steps: int, floor: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
    prog = jnp.clip((step - warmup_steps)
                    / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return peak_lr * warm * cos
