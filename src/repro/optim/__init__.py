from repro.optim.adamw import (  # noqa: F401
    AdamWState, adamw_init, adamw_update)
from repro.optim.schedule import wsd_schedule  # noqa: F401
from repro.optim.compression import (  # noqa: F401
    compress_int8, decompress_int8, compressed_psum)
