"""Fault-tolerant checkpointing: atomic, asynchronous, elastic.

Design (orbax-free, npz-based, but with the production invariants):

  * **Atomicity** — writes go to ``step_<N>.tmp/`` and are ``os.rename``d to
    ``step_<N>/`` only after every shard file and the manifest are fsynced.
    A crash mid-write leaves a ``.tmp`` dir that restore ignores and the next
    save garbage-collects.
  * **Asynchrony** — ``save_async`` snapshots device arrays to host
    (``jax.device_get`` is the only synchronous part) and hands serialization
    to a writer thread, so the train loop overlaps checkpoint I/O with the
    next step (the paper's "hide maintenance off the critical path" lesson
    applied to checkpoints).
  * **Elastic restore** — arrays are saved *unsharded* (host-gathered
    logical arrays) with a manifest of shapes/dtypes; restore re-shards onto
    whatever mesh the restart runs with (``restore(..., shardings=...)``),
    so a 256-chip checkpoint restores on 512 chips and vice versa.
  * **Retention** — ``keep`` newest steps are retained, the rest GC'd.

For multi-controller deployment, rank 0 writes and other ranks barrier on
the manifest; the single-process container exercises the same code path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    """Flatten nested dict/NamedTuple/list pytrees to {path: leaf}."""
    out = {}
    if isinstance(tree, dict):
        items = tree.items()
    elif hasattr(tree, "_asdict"):
        items = tree._asdict().items()
    elif isinstance(tree, (list, tuple)):
        items = ((str(i), v) for i, v in enumerate(tree))
    else:
        return {prefix.rstrip("/"): tree}
    for k, v in items:
        out.update(_flatten(v, f"{prefix}{k}/"))
    return out


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(directory, d, "MANIFEST.json"))]
    return max(steps) if steps else None


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._writer: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any) -> str:
        """Synchronous atomic save. Returns the final directory."""
        host = {k: np.asarray(jax.device_get(v))
                for k, v in _flatten(tree).items()}
        return self._write(step, host)

    def save_async(self, step: int, tree: Any) -> None:
        """Device->host snapshot now; file I/O on the writer thread."""
        self.wait()  # one outstanding save (bounds host memory)
        host = {k: np.asarray(jax.device_get(v))
                for k, v in _flatten(tree).items()}

        def run():
            try:
                self._write(step, host)
            except BaseException as e:  # surfaced by wait()
                self._error = e

        self._writer = threading.Thread(target=run, daemon=True,
                                        name=f"ckpt-{step}")
        self._writer.start()

    def wait(self) -> None:
        if self._writer is not None:
            self._writer.join()
            self._writer = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host: dict) -> str:
        final = os.path.join(self.directory, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "time": time.time(), "arrays": {}}
        for i, (path, arr) in enumerate(sorted(host.items())):
            fname = f"arr_{i:05d}.npy"
            with open(os.path.join(tmp, fname), "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            manifest["arrays"][path] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype)}
        mpath = os.path.join(tmp, "MANIFEST.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # the atomic commit point
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(
            (int(d.split("_")[1]) for d in os.listdir(self.directory)
             if d.startswith("step_") and not d.endswith(".tmp")),
            reverse=True)
        for s in steps[self.keep:]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)
        for d in os.listdir(self.directory):  # crashed writes
            if d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.directory, d),
                              ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def restore(self, step: int, like: Any,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        NamedShardings — this is the elastic-resharding path: the flat host
        arrays are placed directly onto the *new* mesh layout."""
        d = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        flat_like = _flatten(like)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        loaded = {}
        for path, ref in flat_like.items():
            meta = manifest["arrays"].get(path)
            if meta is None:
                raise KeyError(f"checkpoint missing array: {path}")
            arr = np.load(os.path.join(d, meta["file"]))
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"{path}: checkpoint shape {arr.shape} != {ref.shape}")
            sh = flat_shard.get(path)
            loaded[path] = (jax.device_put(arr, sh) if sh is not None
                            else jax.device_put(arr))
        return _unflatten_like(like, loaded)


def _unflatten_like(like: Any, flat: dict, prefix: str = ""):
    if isinstance(like, dict):
        return {k: _unflatten_like(v, flat, f"{prefix}{k}/")
                for k, v in like.items()}
    if hasattr(like, "_asdict"):
        vals = {k: _unflatten_like(v, flat, f"{prefix}{k}/")
                for k, v in like._asdict().items()}
        return type(like)(**vals)
    if isinstance(like, (list, tuple)):
        return type(like)(
            _unflatten_like(v, flat, f"{prefix}{i}/")
            for i, v in enumerate(like))
    return flat[prefix.rstrip("/")]
