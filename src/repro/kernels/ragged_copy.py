"""Ragged row copy: the maintenance kernel (the ``mmap`` replay loop).

``view[slots[i]] = pool[offsets[i]]`` for i in [0, M): both source and
destination rows are data-dependent.  On TPU this is pure scalar-prefetch
territory — ``offsets`` addresses the *input* BlockSpec, ``slots``
addresses the *output* BlockSpec, and the grid walks the request list
while the DMA engine double-buffers rows.  The destination view is passed
as a donated input aliased to the output (``input_output_aliases``), so
un-touched rows never move: the kernel's byte cost is
``2 x M x row_bytes``, the same economics as the paper's per-slot remap
(and like ``mmap``, later duplicates win — the grid is sequential).

This is the device half of the Shortcut-EH / Shortcut-KV *update request*
replay; ``core.rewiring.remap_slots`` is its XLA fallback.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret


def _copy_kernel(slots_ref, offsets_ref, pool_ref, view_ref, out_ref):
    del slots_ref, offsets_ref, view_ref
    out_ref[...] = pool_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ragged_copy(view, pool, slots, offsets, *,
                interpret: Optional[bool] = None) -> jax.Array:
    """view: (V, row); pool: (P, row); slots/offsets: (M,) int32.
    Returns the updated view (aliased in-place on TPU)."""
    M = slots.shape[0]
    row = view.shape[1:]
    assert pool.shape[1:] == row, (pool.shape, view.shape)
    blk = (1,) + row
    zeros = (0,) * len(row)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,        # slots + offsets in SMEM
        grid=(M,),
        in_specs=[
            pl.BlockSpec(blk, lambda i, sl, of: (of[i],) + zeros),  # pool
            pl.BlockSpec(blk, lambda i, sl, of: (sl[i],) + zeros),  # view
        ],
        out_specs=pl.BlockSpec(blk, lambda i, sl, of: (sl[i],) + zeros),
    )
    return pl.pallas_call(
        _copy_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(view.shape, view.dtype),
        input_output_aliases={3: 0},  # args: slots, offsets, pool, view
        interpret=resolve_interpret(interpret),
    )(slots.astype(jnp.int32), offsets.astype(jnp.int32), pool, view)
