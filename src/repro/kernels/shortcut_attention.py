"""Decode attention over the *contiguous shortcut view* — the "after" of
the paper's before/after.

The view is (B, KV, S_cap, hd): token positions are pure address
arithmetic, so the kernel is a straight stream of kv tiles into VMEM with
the online-softmax recurrence in scratch — zero index traffic.  ``ctx_len``
arrives via scalar prefetch and masks the dead tail; tiles entirely beyond
``ctx_len`` are skipped structurally (``pl.when``), so the DMA schedule
shortens with the live context exactly like the paper's shortcut lookup
touches only mapped pages.

Grid: (B, KV, n_s), s innermost carrying the recurrence.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

_NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bs: int, n_s: int, window: Optional[int],
            softcap: Optional[float], scale: float):
    b = pl.program_id(0)
    sj = pl.program_id(2)
    ctx = len_ref[b]

    @pl.when(sj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lo = sj * bs
    live_tile = lo < ctx
    if window is not None:
        live_tile = jnp.logical_and(live_tile,
                                    lo + bs - 1 > ctx - 1 - window)

    @pl.when(live_tile)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale     # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)             # (bs, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (G, bs)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        pos = lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = pos < ctx
        if window is not None:
            mask &= pos > ctx - 1 - window
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_ref[...]                              # (G,)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # (G, hd)
        m_ref[...] = m_new

    @pl.when(sj == n_s - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "bs", "interpret"))
def shortcut_attention(q, k_view, v_view, ctx_len, *,
                       window: Optional[int] = None,
                       softcap: Optional[float] = None,
                       bs: int = 512, interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, KV, G, hd); k_view/v_view: (B, KV, S_cap, hd);
    ctx_len: (B,) int32 live tokens.  Returns (B, KV, G, hd)."""
    B, KV, G, hd = q.shape
    S = k_view.shape[2]
    bs = min(bs, S)
    pad = (-S) % bs
    if pad:
        k_view = jnp.pad(k_view, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_view = jnp.pad(v_view, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_s = (S + pad) // bs
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, j, ln: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, j, ln: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, j, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _kernel, bs=bs, n_s=n_s, window=window, softcap=softcap,
        scale=hd ** -0.5)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=resolve_interpret(interpret),
    )(ctx_len.astype(jnp.int32), q, k_view, v_view)
