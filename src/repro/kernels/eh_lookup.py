"""Fused extendible-hashing lookup kernels — the paper's hot loop on TPU.

Two access paths, mirroring §2 of the paper:

  * :func:`eh_lookup`      — the *traditional* path: hash -> directory
    gather -> bucket gather -> probe.  Two data-dependent indirections.
  * :func:`shortcut_lookup`— the *shortcut* path: hash -> direct view
    probe.  One indirection: the composed view (``rewiring.compose``) plays
    the role of the page table having pre-resolved the mapping.

Both exist in a **sharded** form (:func:`sharded_eh_lookup`,
:func:`sharded_shortcut_lookup`) for the partitioned index
(``core/sharded_eh.py``): the per-shard structures are stacked on a
leading shard axis and the shard loop is a *grid dimension* of one
``pallas_call`` — N shards share a single kernel specialization instead
of recompiling (or even re-dispatching) per shard.  The single-shard
entry points are the N=1 degenerate case of the same kernel, so there is
exactly one lookup-kernel body in the tree (``_resolve_tile``).

:func:`sharded_routed_lookup` is the **per-shard routed** form: it takes
a per-shard ``two_level`` flag vector (scalar-prefetched alongside both
depth vectors) and resolves each shard through the directory or the
composed view *inside the same dispatch* — a mixed-sync shard group
(some shards gated traditional, some shortcut-eligible) no longer
demotes the whole batch.  The flag is uniform per grid cell, so each
cell runs exactly one ``pl.when`` arm of the shared body.

:func:`stacked_shortcut_lookup` is the flat (single-shard) path against
the stacked **primary** operand storage (``runtime/operand_cache``,
DESIGN.md §4.4): the shard index arrives by scalar prefetch and the
block index maps select that shard's block of the ``(N, V, S)`` stack
directly — no per-shard slice is ever materialized on device.

TPU adaptation notes (DESIGN.md §2): the VPU has no scatter/gather to HBM,
so both kernels keep the directory and bucket pages VMEM-resident (block =
one shard's full structure; for the assigned sizes — 2^14 slots x 64-slot
buckets of u32 pairs — this is ~8 MiB, within VMEM; sharding is exactly
what keeps *growing* structures inside this regime, DESIGN.md §2.4).  Per
key-tile the kernel computes the multiplicative hashes vectorized on the
VPU, then resolves the data-dependent row reads with a ``fori_loop`` of
dynamic slices (sublane-dynamic addressing, which Mosaic supports on
VMEM).  The probe itself is vectorized across the bucket row.
Directories larger than VMEM are exactly the regime where the paper's
lesson applies: don't chase pointers — compose the view first
(``shortcut_lookup``), shard the structure, or fall back to the XLA
gather path (``core.extendible_hashing``).

``interpret=None`` auto-detects the execution mode (compiled on TPU,
interpreted elsewhere — ``kernels/backend.py``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hashing
from repro.kernels.backend import resolve_interpret

# hashing.HASH_C1/C2 and the sentinels are python ints (NOT jnp scalars: a
# traced module-level constant would be captured by the kernel, which
# pallas forbids); cast at use sites.  Local aliases for readability.
EMPTY_KEY = hashing.EMPTY_SENTINEL
MISS = hashing.MISS_SENTINEL


def _probe_row(row_k, row_v, key, slots: int):
    """Vectorized linear probe of one bucket row (slots,)->value or MISS.

    Same masked-probe core as the XLA path (``hashing.probe_hit``); the
    helpers trace cleanly inside the kernel because they only use
    elementwise/cumsum/argmax ops the VPU supports."""
    pos = hashing.probe_positions(key, slots)
    found, j = hashing.probe_hit(row_k[pos], key)
    return jnp.where(found, row_v[pos[j]], jnp.uint32(MISS))


def _resolve_tile(keys, g, dir_ref, bk_ref, bv_ref, out_ref, *,
                  tile: int, slots: int, two_level: bool):
    """THE lookup body: resolve one key tile against one shard's pages.

    Shared by the static kernels and both arms of the routed kernel, so
    there is still exactly one probe loop in the tree."""
    slot = hashing.dir_slot(hashing.hash_dir(keys), g)

    def body(i, _):
        key = keys[i]
        s = slot[i]
        if two_level:
            row = dir_ref[0, s]         # indirection 1: directory
        else:
            row = s                     # shortcut: slot IS the row
        row_k = bk_ref[0, row]          # indirection 2 (or 1): bucket page
        row_v = bv_ref[0, row]
        out_ref[0, i] = _probe_row(row_k, row_v, key, slots)
        return 0

    jax.lax.fori_loop(0, tile, body, 0)


def _lookup_kernel(gd_ref, keys_ref, dir_ref, bk_ref, bv_ref, out_ref, *,
                   tile: int, slots: int, two_level: bool):
    """One (shard, key-tile) grid cell, single-mode (``two_level`` is a
    *static* python bool baked into the specialization).

    Blocks carry a leading unit shard dim; the shard's global depth comes
    from the scalar-prefetch vector, indexed by the shard grid position —
    the only per-shard scalar, which is what lets every shard share this
    one specialization."""
    g = gd_ref[pl.program_id(0)]
    _resolve_tile(keys_ref[0], g, dir_ref, bk_ref, bv_ref, out_ref,
                  tile=tile, slots=slots, two_level=two_level)


def _routed_kernel(sc_ref, keys_ref, dir_ref, bk_ref, bv_ref, vk_ref,
                   vv_ref, out_ref, *, tile: int, slots: int):
    """One (shard, key-tile) grid cell, per-shard routed.

    ``sc_ref`` is the packed (3, N) scalar-prefetch block: row 0 the
    per-shard ``two_level`` flags (1 → resolve traditionally through the
    directory, 0 → through the composed view), row 1 the traditional
    global depths, row 2 the view log2 sizes.  The flag is uniform
    across a grid cell (it is per *shard*), so each cell runs exactly
    one ``pl.when`` arm — a mixed-sync shard group still fuses into ONE
    dispatch instead of demoting the whole batch to the traditional
    kernel."""
    s = pl.program_id(0)
    two_level = sc_ref[0, s]
    keys = keys_ref[0]

    @pl.when(two_level != 0)
    def _traditional():
        _resolve_tile(keys, sc_ref[1, s], dir_ref, bk_ref, bv_ref,
                      out_ref, tile=tile, slots=slots, two_level=True)

    @pl.when(two_level == 0)
    def _shortcut():
        _resolve_tile(keys, sc_ref[2, s], dir_ref, vk_ref, vv_ref,
                      out_ref, tile=tile, slots=slots, two_level=False)


def _run(keys, directory, bucket_keys, bucket_vals, global_depths, *,
         two_level: bool, tile: int, interpret: Optional[bool]):
    """Shared driver: keys (N, K); directory (N, D); buckets (N, C, S);
    global_depths (N,).  Grid = (shards, key tiles); every shard reuses
    the same compiled kernel — one ``pallas_call``, not N."""
    N, n = keys.shape
    pad = (-n) % tile
    if pad:
        keys = jnp.pad(keys, ((0, 0), (0, pad)))
    nt = (n + pad) // tile
    D = directory.shape[1]
    C, S = bucket_keys.shape[1:]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,          # per-shard global depths in SMEM
        grid=(N, nt),
        in_specs=[
            pl.BlockSpec((1, tile), lambda s, i, gd: (s, i)),
            pl.BlockSpec((1, D), lambda s, i, gd: (s, 0)),    # VMEM-resident
            pl.BlockSpec((1, C, S), lambda s, i, gd: (s, 0, 0)),
            pl.BlockSpec((1, C, S), lambda s, i, gd: (s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda s, i, gd: (s, i)),
    )
    kernel = functools.partial(_lookup_kernel, tile=tile, slots=S,
                               two_level=two_level)
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, n + pad), jnp.uint32),
        interpret=resolve_interpret(interpret),
    )(global_depths.astype(jnp.int32), keys.astype(jnp.uint32),
      directory.astype(jnp.int32), bucket_keys, bucket_vals)
    return out[:, :n]


# ---------------------------------------------------------------------------
# Single-shard entry points (N=1 degenerate case of the sharded kernel).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def eh_lookup(keys, directory, bucket_keys, bucket_vals, global_depth, *,
              tile: int = 256, interpret: Optional[bool] = None):
    """Traditional EH lookup: keys (N,) -> values (N,) (MISS on absent).

    directory: (D,) int32; bucket_keys/vals: (C, S) uint32."""
    return _run(keys[None], directory[None], bucket_keys[None],
                bucket_vals[None],
                jnp.reshape(jnp.asarray(global_depth, jnp.int32), (1,)),
                two_level=True, tile=tile, interpret=interpret)[0]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def shortcut_lookup(keys, view_keys, view_vals, global_depth, *,
                    tile: int = 256, interpret: Optional[bool] = None):
    """Shortcut lookup over the composed view: one indirection fewer.

    view_keys/vals: (2^g_cap, S) — slot-indexed bucket pages."""
    dummy_dir = jnp.zeros((1, 1), jnp.int32)  # unused in shortcut mode
    return _run(keys[None], dummy_dir, view_keys[None], view_vals[None],
                jnp.reshape(jnp.asarray(global_depth, jnp.int32), (1,)),
                two_level=False, tile=tile, interpret=interpret)[0]


# ---------------------------------------------------------------------------
# Batched cross-shard entry points (``core/sharded_eh.py``): one dispatch,
# one specialization, shard = grid dimension.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def sharded_eh_lookup(keys, directories, bucket_keys, bucket_vals,
                      global_depths, *, tile: int = 256,
                      interpret: Optional[bool] = None):
    """Traditional lookup across N stacked shards.

    keys: (N, K) — shard-bucketized, padded to a static per-shard
    capacity (pad lanes return MISS and are dropped by the caller's
    scatter-back); directories: (N, D); bucket_keys/vals: (N, C, S);
    global_depths: (N,).  Returns (N, K) uint32."""
    return _run(keys, directories, bucket_keys, bucket_vals, global_depths,
                two_level=True, tile=tile, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def sharded_shortcut_lookup(keys, view_keys, view_vals, global_depths, *,
                            tile: int = 256,
                            interpret: Optional[bool] = None):
    """Shortcut lookup across N stacked shards (views (N, V, S))."""
    dummy_dir = jnp.zeros((keys.shape[0], 1), jnp.int32)
    return _run(keys, dummy_dir, view_keys, view_vals, global_depths,
                two_level=False, tile=tile, interpret=interpret)


def _stacked_select_kernel(sc_ref, keys_ref, vk_ref, vv_ref, out_ref, *,
                           tile: int, slots: int):
    """One key-tile grid cell against ONE shard's block of the stacked
    view, block-selected by the scalar-prefetched shard index (the block
    index maps read ``sc_ref[0]``) — the stack never leaves its resting
    place and no per-shard slice is materialized.  ``sc_ref[1]`` is the
    selected shard's view log2."""
    _resolve_tile(keys_ref[0], sc_ref[1], None, vk_ref, vv_ref, out_ref,
                  tile=tile, slots=slots, two_level=False)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def stacked_shortcut_lookup(keys, view_keys, view_vals, view_log2s,
                            shard, *, tile: int = 256,
                            interpret: Optional[bool] = None):
    """Single-shard shortcut lookup resolved straight off the stacked
    primary storage (``runtime/operand_cache``, DESIGN.md §4.4).

    keys: (K,); view_keys/vals: the full (N, V, S) stacks; view_log2s:
    (N,); ``shard`` selects which block the grid reads — via scalar
    prefetch, so all shards (and all shard *indices*) share one compiled
    specialization, and the flat per-shard lookup path needs no device
    copy of its shard's view."""
    n = keys.shape[0]
    pad = (-n) % tile
    if pad:
        keys = jnp.pad(keys, ((0, pad),))
    nt = (n + pad) // tile
    V, S = view_keys.shape[1:]
    sidx = jnp.asarray(shard, jnp.int32)
    scalars = jnp.stack([sidx, view_log2s.astype(jnp.int32)[sidx]])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,          # (shard, its view log2) in SMEM
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i, sc: (0, i)),
            pl.BlockSpec((1, V, S), lambda i, sc: (sc[0], 0, 0)),
            pl.BlockSpec((1, V, S), lambda i, sc: (sc[0], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i, sc: (0, i)),
    )
    kernel = functools.partial(_stacked_select_kernel, tile=tile, slots=S)
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, n + pad), jnp.uint32),
        interpret=resolve_interpret(interpret),
    )(scalars, keys.astype(jnp.uint32)[None], view_keys, view_vals)
    return out[0, :n]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def sharded_routed_lookup(keys, directories, bucket_keys, bucket_vals,
                          global_depths, view_keys, view_vals, view_log2s,
                          two_level, *, tile: int = 256,
                          interpret: Optional[bool] = None):
    """Per-shard routed lookup across N stacked shards: ONE dispatch
    even when the shards disagree about their access path.

    ``two_level`` is the per-shard flag vector (N,): nonzero shards
    resolve traditionally (directories (N, D) + bucket pools (N, C, S)
    at ``global_depths``), zero shards resolve through their composed
    views ((N, V, S), slot-indexed at ``view_log2s``; rows past
    ``2**view_log2s[s]`` are pad and never indexed).  Both operand sets
    ride in VMEM per grid cell — the price of not demoting a mixed
    batch is one extra resident block pair, which the operand cache
    (``runtime/operand_cache``) keeps warm anyway.  Returns (N, K)
    uint32 in the same padded layout as :func:`sharded_eh_lookup`.
    """
    N, n = keys.shape
    if bucket_keys.shape[-1] != view_keys.shape[-1]:
        raise ValueError(
            f"bucket/view slot widths differ: {bucket_keys.shape[-1]} "
            f"vs {view_keys.shape[-1]}")
    pad = (-n) % tile
    if pad:
        keys = jnp.pad(keys, ((0, 0), (0, pad)))
    nt = (n + pad) // tile
    D = directories.shape[1]
    C, S = bucket_keys.shape[1:]
    V = view_keys.shape[1]
    scalars = jnp.stack([two_level.astype(jnp.int32),
                         global_depths.astype(jnp.int32),
                         view_log2s.astype(jnp.int32)])        # (3, N)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,          # the packed (3, N) block in SMEM
        grid=(N, nt),
        in_specs=[
            pl.BlockSpec((1, tile), lambda s, i, sc: (s, i)),
            pl.BlockSpec((1, D), lambda s, i, sc: (s, 0)),
            pl.BlockSpec((1, C, S), lambda s, i, sc: (s, 0, 0)),
            pl.BlockSpec((1, C, S), lambda s, i, sc: (s, 0, 0)),
            pl.BlockSpec((1, V, S), lambda s, i, sc: (s, 0, 0)),
            pl.BlockSpec((1, V, S), lambda s, i, sc: (s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda s, i, sc: (s, i)),
    )
    kernel = functools.partial(_routed_kernel, tile=tile, slots=S)
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, n + pad), jnp.uint32),
        interpret=resolve_interpret(interpret),
    )(scalars, keys.astype(jnp.uint32), directories.astype(jnp.int32),
      bucket_keys, bucket_vals, view_keys, view_vals)
    return out[:, :n]
