"""Fused extendible-hashing lookup kernels — the paper's hot loop on TPU.

Two access paths, mirroring §2 of the paper:

  * :func:`eh_lookup`      — the *traditional* path: hash -> directory
    gather -> bucket gather -> probe.  Two data-dependent indirections.
  * :func:`shortcut_lookup`— the *shortcut* path: hash -> direct view
    probe.  One indirection: the composed view (``rewiring.compose``) plays
    the role of the page table having pre-resolved the mapping.

TPU adaptation notes (DESIGN.md §2): the VPU has no scatter/gather to HBM,
so both kernels keep the directory and bucket pages VMEM-resident (block =
the full structure; for the assigned sizes — 2^14 slots x 64-slot buckets
of u32 pairs — this is ~8 MiB, within VMEM).  Per key-tile the kernel
computes the multiplicative hashes vectorized on the VPU, then resolves
the data-dependent row reads with a ``fori_loop`` of dynamic slices
(sublane-dynamic addressing, which Mosaic supports on VMEM).  The probe
itself is vectorized across the bucket row.  Directories larger than VMEM
are exactly the regime where the paper's lesson applies: don't chase
pointers — compose the view first (``shortcut_lookup``) or fall back to
the XLA gather path (``core.extendible_hashing``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import hashing

# hashing.HASH_C1/C2 and the sentinels are python ints (NOT jnp scalars: a
# traced module-level constant would be captured by the kernel, which
# pallas forbids); cast at use sites.  Local aliases for readability.
EMPTY_KEY = hashing.EMPTY_SENTINEL
MISS = hashing.MISS_SENTINEL


def _probe_row(row_k, row_v, key, slots: int):
    """Vectorized linear probe of one bucket row (slots,)->value or MISS.

    Same masked-probe core as the XLA path (``hashing.probe_hit``); the
    helpers trace cleanly inside the kernel because they only use
    elementwise/cumsum/argmax ops the VPU supports."""
    pos = hashing.probe_positions(key, slots)
    found, j = hashing.probe_hit(row_k[pos], key)
    return jnp.where(found, row_v[pos[j]], jnp.uint32(MISS))


def _lookup_kernel(gd_ref, keys_ref, dir_ref, bk_ref, bv_ref, out_ref, *,
                   tile: int, slots: int, two_level: bool):
    g = gd_ref[0]
    keys = keys_ref[...]
    slot = hashing.dir_slot(hashing.hash_dir(keys), g)

    def body(i, _):
        key = keys[i]
        s = slot[i]
        if two_level:
            row = dir_ref[s]            # indirection 1: directory
        else:
            row = s                     # shortcut: slot IS the row
        row_k = bk_ref[row]             # indirection 2 (or 1): bucket page
        row_v = bv_ref[row]
        out_ref[i] = _probe_row(row_k, row_v, key, slots)
        return 0

    jax.lax.fori_loop(0, tile, body, 0)


def _run(keys, directory, bucket_keys, bucket_vals, global_depth, *,
         two_level: bool, tile: int, interpret: bool):
    n = keys.shape[0]
    pad = (-n) % tile
    if pad:
        keys = jnp.pad(keys, (0, pad))
    nt = (n + pad) // tile
    D = directory.shape[0]
    C, S = bucket_keys.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,          # global depth in SMEM
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i, gd: (i,)),
            pl.BlockSpec((D,), lambda i, gd: (0,)),       # VMEM-resident
            pl.BlockSpec((C, S), lambda i, gd: (0, 0)),
            pl.BlockSpec((C, S), lambda i, gd: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i, gd: (i,)),
    )
    kernel = functools.partial(_lookup_kernel, tile=tile, slots=S,
                               two_level=two_level)
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n + pad,), jnp.uint32),
        interpret=interpret,
    )(jnp.asarray([global_depth], jnp.int32), keys.astype(jnp.uint32),
      directory.astype(jnp.int32), bucket_keys, bucket_vals)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def eh_lookup(keys, directory, bucket_keys, bucket_vals, global_depth, *,
              tile: int = 256, interpret: bool = True):
    """Traditional EH lookup: keys (N,) -> values (N,) (MISS on absent).

    directory: (D,) int32; bucket_keys/vals: (C, S) uint32."""
    return _run(keys, directory, bucket_keys, bucket_vals, global_depth,
                two_level=True, tile=tile, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def shortcut_lookup(keys, view_keys, view_vals, global_depth, *,
                    tile: int = 256, interpret: bool = True):
    """Shortcut lookup over the composed view: one indirection fewer.

    view_keys/vals: (2^g_cap, S) — slot-indexed bucket pages."""
    dummy_dir = jnp.zeros((1,), jnp.int32)  # unused in shortcut mode
    return _run(keys, dummy_dir, view_keys, view_vals, global_depth,
                two_level=False, tile=tile, interpret=interpret)
