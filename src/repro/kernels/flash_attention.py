"""Flash attention (train/prefill) as a Pallas TPU kernel.

Tiling: grid = (B, KV, n_q, n_kv) with the kv axis innermost ("arbitrary"
semantics — it carries the online-softmax recurrence in VMEM scratch).
Per step the kernel holds one q tile (G, bq, hd), one k/v tile (bkv, hd)
and the f32 accumulator (G, bq, hd) in VMEM; with the defaults
(bq=256, bkv=512, hd<=256, G<=8) the working set stays well under 16 MiB
and every matmul dimension is a multiple of the 128-lane MXU width.

Causal masking is structural: kv tiles strictly above the diagonal are
skipped with ``pl.when`` (no wasted MXU work), the diagonal tile applies
the triangular mask, sliding windows additionally mask from below.

GQA is handled by folding the G query heads of one kv head into the q
tile's leading dim — the kv tile is loaded ONCE per group (the bandwidth
win GQA exists for).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bkv: int, n_kv: int, causal: bool,
                  window: Optional[int], softcap: Optional[float],
                  q_offset: int, scale: float, skv: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = qi * bq + q_offset           # global position of q tile start
    k_lo = kj * bkv
    # structural skip: whole kv tile above the causal diagonal, or whole
    # tile below the window
    in_range = True
    if causal:
        in_range = k_lo <= q_lo + bq - 1
    if window is not None:
        in_range = jnp.logical_and(in_range,
                                   k_lo + bkv - 1 > q_lo - window)

    @pl.when(in_range)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale    # (G, bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bkv, hd)
        v = v_ref[0, 0].astype(jnp.float32)            # (bkv, hd)
        s = jax.lax.dot_general(
            q, k, (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)        # (G, bq, bkv)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = kpos < skv          # padded kv tail is never attended
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask[None], s, _NEG_INF)
        m_prev = m_ref[...]                             # (G, bq)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        pv = jax.lax.dot_general(
            p, v, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)         # (G, bq, hd)
        acc_ref[...] = acc_ref[...] * corr[..., None] + pv
        m_ref[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "bq", "bkv",
                              "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    bq: int = 256, bkv: int = 512,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, KV, G, Sq, hd); k/v: (B, KV, Skv, hd) -> like q.

    Sq/Skv are padded to tile multiples internally; q positions are
    right-aligned against Skv (prefill convention)."""
    B, KV, G, Sq, hd = q.shape
    Skv = k.shape[2]
    bq = min(bq, Sq)
    bkv = min(bkv, Skv)
    pad_q = (-Sq) % bq
    pad_kv = (-Skv) % bkv
    q_offset = Skv - Sq                 # right alignment
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    n_q = (Sq + pad_q) // bq
    n_kv = (Skv + pad_kv) // bkv
    kernel = functools.partial(
        _flash_kernel, bq=bq, bkv=bkv, n_kv=n_kv, causal=causal,
        window=window, softcap=softcap, q_offset=q_offset,
        scale=hd ** -0.5, skv=Skv)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, G, bq, hd),
                         lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bkv, hd),
                         lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, bq, hd),
                               lambda b, h, i, j: (b, h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (B, KV, G, Sq + pad_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, bq), jnp.float32),
            pltpu.VMEM((G, bq), jnp.float32),
            pltpu.VMEM((G, bq, hd), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(q, k, v)
    return out[:, :, :, :Sq]
