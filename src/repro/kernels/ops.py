"""Public jit'd entry points for the Pallas kernels.

Each op accepts *model-layout* arrays, adapts them to the kernel layouts,
and dispatches to the kernel.  Execution mode is auto-detected (compiled
on TPU, interpreted elsewhere — ``kernels/backend.py``); set
``REPRO_PALLAS_INTERPRET=1``/``0`` to force it process-wide.  ``ref.py``
holds the pure-jnp oracles the tests sweep against.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref  # noqa: F401  (re-exported for tests)
from repro.kernels.eh_lookup import eh_lookup, shortcut_lookup
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ragged_copy import ragged_copy
from repro.kernels.shortcut_attention import shortcut_attention

_ENV = os.environ.get("REPRO_PALLAS_INTERPRET")
#: None = auto-detect per backend (kernels/backend.resolve_interpret);
#: "1"/"0" in the environment force interpret/compiled respectively.
INTERPRET = None if _ENV is None else _ENV == "1"


def mha_forward(q, k, v, *, causal: bool = True,
                window: Optional[int] = None,
                softcap: Optional[float] = None,
                bq: int = 256, bkv: int = 512) -> jax.Array:
    """Model-layout flash attention.

    q: (B, S, H, hd); k/v: (B, S, KV, hd) -> (B, S, H, hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qk = q.reshape(B, Sq, KV, G, hd).transpose(0, 2, 3, 1, 4)
    kk = k.transpose(0, 2, 1, 3)
    vk = v.transpose(0, 2, 1, 3)
    o = flash_attention(qk, kk, vk, causal=causal, window=window,
                        softcap=softcap, bq=bq, bkv=bkv,
                        interpret=INTERPRET)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)


def decode_shortcut(q, view_k, view_v, ctx_len, *,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    bs: int = 512) -> jax.Array:
    """Serve-layout shortcut decode.

    q: (B, H, hd); view_k/v: (B, S_cap, KV, hd); ctx_len: (B,).
    Returns (B, H, hd)."""
    B, H, hd = q.shape
    KV = view_k.shape[2]
    G = H // KV
    qk = q.reshape(B, KV, G, hd)
    kk = view_k.transpose(0, 2, 1, 3)
    vk = view_v.transpose(0, 2, 1, 3)
    o = shortcut_attention(qk, kk, vk, ctx_len, window=window,
                           softcap=softcap, bs=bs, interpret=INTERPRET)
    return o.reshape(B, H, hd)


def decode_paged(q, k_pool, v_pool, block_tables, seq_lens, *,
                 softcap: Optional[float] = None) -> jax.Array:
    """Serve-layout paged decode.

    q: (B, H, hd); pools: (nblocks, bs, KV, hd) (cache layout);
    block_tables: (B, MB); seq_lens: (B,).  Returns (B, H, hd)."""
    B, H, hd = q.shape
    KV = k_pool.shape[2]
    G = H // KV
    qk = q.reshape(B, KV, G, hd)
    kp = k_pool.transpose(0, 2, 1, 3)   # (nblocks, KV, bs, hd)
    vp = v_pool.transpose(0, 2, 1, 3)
    o = paged_attention(qk, kp, vp, block_tables, seq_lens,
                        softcap=softcap, interpret=INTERPRET)
    return o.reshape(B, H, hd)


def eh_lookup_op(keys, st, *, tile: int = 256) -> jax.Array:
    """Traditional fused lookup against an ``EHState``."""
    D = 1 << int(st.max_global_depth)
    return eh_lookup(keys, st.directory[:D], st.bucket_keys,
                     st.bucket_vals, st.global_depth, tile=tile,
                     interpret=INTERPRET)


def shortcut_lookup_op(keys, view_keys, view_vals, global_depth, *,
                       tile: int = 256) -> jax.Array:
    """Shortcut fused lookup against a composed view."""
    return shortcut_lookup(keys, view_keys, view_vals, global_depth,
                           tile=tile, interpret=INTERPRET)


def remap_rows(view, pool, slots, offsets) -> jax.Array:
    """Maintenance replay: ``view[slots] = pool[offsets]`` (last wins)."""
    return ragged_copy(view, pool, slots, offsets, interpret=INTERPRET)
