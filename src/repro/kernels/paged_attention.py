"""Decode attention through the block-table indirection — the
"traditional directory" access path, as a Pallas TPU kernel.

The block table plays the paper's pointer directory: each kv tile's HBM
address is *data-dependent*.  On TPU the idiomatic mechanism is scalar
prefetch: the (B, MB) block table rides in SMEM and the k/v BlockSpec
``index_map`` dereferences it, so the DMA engine chases the indirection
one step ahead of compute (the hardware page-walk analogue).  Dead table
entries (-1) are clamped in the index_map and masked off via ``seq_lens``.

Grid: (B, KV, MB), MB innermost carrying the online-softmax recurrence.
Compare with ``shortcut_attention.py``: identical math, but every tile
fetch costs an SMEM table load + an unpredictable HBM address — the
two-indirection cost the shortcut view removes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

_NEG_INF = -1e30


def _kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, bs: int, mb: int,
            softcap: Optional[float], scale: float):
    b = pl.program_id(0)
    mj = pl.program_id(2)
    ctx = lens_ref[b]

    @pl.when(mj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lo = mj * bs                       # logical position of this block
    live = jnp.logical_and(tables_ref[b, mj] >= 0, lo < ctx)

    @pl.when(live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (bs, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (G, bs)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        pos = lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < ctx, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(mj == mb - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("softcap", "interpret"))
def paged_attention(q, k_pool, v_pool, block_tables, seq_lens, *,
                    softcap: Optional[float] = None,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, KV, G, hd); pools: (nblocks, KV, bs, hd);
    block_tables: (B, MB) int32 (-1 unset); seq_lens: (B,) int32.
    Returns (B, KV, G, hd)."""
    B, KV, G, hd = q.shape
    nblocks, _, bs, _ = k_pool.shape
    MB = block_tables.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,        # block table + seq lens in SMEM
        grid=(B, KV, MB),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda b, h, m, tbl, ln: (b, h, 0, 0)),
            # the indirection: tile address comes from the table
            # (-1 entries clamp to block 0; the kernel masks them off)
            pl.BlockSpec((1, 1, bs, hd),
                         lambda b, h, m, tbl, ln: (
                             jnp.maximum(tbl[b, m], 0), h, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd),
                         lambda b, h, m, tbl, ln: (
                             jnp.maximum(tbl[b, m], 0), h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, m, tbl, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _kernel, bs=bs, mb=MB, softcap=softcap, scale=hd ** -0.5)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=resolve_interpret(interpret),
    )(block_tables.astype(jnp.int32), seq_lens.astype(jnp.int32),
      q, k_pool, v_pool)
