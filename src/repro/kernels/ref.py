"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Deliberately naive implementations: full score matrices, explicit gathers —
independent of both the kernels and the model's blockwise code paths.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None) -> jax.Array:
    """q: (B, KV, G, Sq, hd); k/v: (B, KV, Skv, hd) -> (B, KV, G, Sq, hd)."""
    B, KV, G, Sq, hd = q.shape
    Skv = k.shape[2]
    s = jnp.einsum("bkgqd,bksd->bkgqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    if softcap is not None:
        s = softcap_ref(s, softcap)
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)  # right-aligned
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def softcap_ref(x, cap):
    return cap * jnp.tanh(x / cap)


def decode_attention_ref(q, k_ctx, v_ctx, ctx_len, *,
                         window: Optional[int] = None,
                         softcap: Optional[float] = None) -> jax.Array:
    """Decode over a contiguous view.

    q: (B, KV, G, hd); k_ctx/v_ctx: (B, KV, S, hd); ctx_len: (B,) live
    tokens (positions [0, ctx_len) are valid) -> (B, KV, G, hd)."""
    B, KV, G, hd = q.shape
    S = k_ctx.shape[2]
    s = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32),
                   k_ctx.astype(jnp.float32)) * hd ** -0.5
    if softcap is not None:
        s = softcap_ref(s, softcap)
    pos = jnp.arange(S)[None]
    live = pos < ctx_len[:, None]
    if window is not None:
        live &= pos > (ctx_len[:, None] - 1 - window)
    s = jnp.where(live[:, None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v_ctx.astype(jnp.float32))
    return o.astype(q.dtype)


def paged_attention_ref(q, k_pool, v_pool, block_tables, seq_lens, *,
                        softcap: Optional[float] = None) -> jax.Array:
    """Decode through the block-table indirection.

    q: (B, KV, G, hd); pools: (nblocks, KV, bs, hd);
    block_tables: (B, MB) int32 (-1 unset); seq_lens: (B,).
    Gathers the context (the two-indirection 'traditional' path), then
    plain decode attention."""
    B = q.shape[0]
    MB = block_tables.shape[1]
    bs = k_pool.shape[2]
    safe = jnp.maximum(block_tables, 0)
    k_ctx = k_pool[safe]                   # (B, MB, KV, bs, hd)
    v_ctx = v_pool[safe]
    k_ctx = k_ctx.transpose(0, 2, 1, 3, 4).reshape(
        B, k_pool.shape[1], MB * bs, k_pool.shape[3])
    v_ctx = v_ctx.transpose(0, 2, 1, 3, 4).reshape(
        B, v_pool.shape[1], MB * bs, v_pool.shape[3])
    return decode_attention_ref(q, k_ctx, v_ctx, seq_lens, softcap=softcap)


def eh_lookup_ref(keys, directory, bucket_keys, bucket_vals,
                  global_depth) -> jax.Array:
    """Batched EH lookup: hash -> directory slot -> bucket probe.

    keys: (N,) uint32; directory: (D,) int32; bucket_keys/vals: (C, S).
    Returns (N,) uint32 values (0xFFFFFFFF on miss)."""
    from repro.core.extendible_hashing import (EMPTY_KEY, MISS, dir_slot,
                                               hash_dir, hash_bucket)
    S = bucket_keys.shape[1]

    def one(key):
        slot = dir_slot(hash_dir(key), global_depth)
        b = directory[slot]
        row_k = bucket_keys[b]
        row_v = bucket_vals[b]
        start = hash_bucket(key) % jnp.uint32(S)
        pos = ((start + jnp.arange(S, dtype=jnp.uint32))
               % jnp.uint32(S)).astype(jnp.int32)
        probed = row_k[pos]
        hit = probed == key
        empties = probed == EMPTY_KEY
        before = jnp.cumsum(empties.astype(jnp.int32)) \
            - empties.astype(jnp.int32)
        live = hit & (before == 0)
        found = jnp.any(live)
        return jnp.where(found, row_v[pos[jnp.argmax(live)]], MISS)

    return jax.vmap(one)(keys.astype(jnp.uint32))


def shortcut_lookup_ref(keys, view_keys, view_vals,
                        global_depth) -> jax.Array:
    """One-indirection variant: slot arithmetic + direct view probe."""
    from repro.core.extendible_hashing import (EMPTY_KEY, MISS, dir_slot,
                                               hash_dir, hash_bucket)
    S = view_keys.shape[1]

    def one(key):
        slot = dir_slot(hash_dir(key), global_depth)
        row_k = view_keys[slot]
        row_v = view_vals[slot]
        start = hash_bucket(key) % jnp.uint32(S)
        pos = ((start + jnp.arange(S, dtype=jnp.uint32))
               % jnp.uint32(S)).astype(jnp.int32)
        probed = row_k[pos]
        hit = probed == key
        empties = probed == EMPTY_KEY
        before = jnp.cumsum(empties.astype(jnp.int32)) \
            - empties.astype(jnp.int32)
        live = hit & (before == 0)
        found = jnp.any(live)
        return jnp.where(found, row_v[pos[jnp.argmax(live)]], MISS)

    return jax.vmap(one)(keys.astype(jnp.uint32))


def ragged_copy_ref(view, pool, slots, offsets) -> jax.Array:
    """view[slots[i]] = pool[offsets[i]] (last write wins)."""
    return view.at[slots].set(pool[offsets])
