"""Pallas execution-mode resolution shared by every kernel entry point.

The kernels take ``interpret: bool | None``.  ``None`` (the default)
means *auto-detect*: compile through Mosaic when the default JAX backend
is a TPU, fall back to the Pallas interpreter everywhere else (CPU CI,
dev containers).  Before this existed the default was a hard-coded
``True``, so a TPU run that forgot to pass ``interpret=False`` silently
executed the hot loop in the (orders-of-magnitude slower) interpreter —
the worst kind of perf bug, because nothing fails.

An explicit ``True``/``False`` always wins over auto-detection;
``kernels/ops.py`` additionally honours the ``REPRO_PALLAS_INTERPRET``
environment override for whole-process forcing.
"""
from __future__ import annotations

from typing import Optional

import jax


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Concrete interpret flag for a ``pl.pallas_call``.

    Called at trace time (``interpret`` is a static argument of every
    kernel's jit wrapper), so the backend probe costs nothing per step.
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)
