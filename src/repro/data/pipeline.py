"""Deterministic, shardable synthetic LM data pipeline.

Every batch is a pure function of ``(seed, step, shard_index)`` — the
property that makes checkpoint/restart bitwise reproducible and elastic
rescaling well-defined: on restore with a different DP degree, the stream
re-partitions by recomputing shard indices, never by replaying host state.

The synthetic stream is a Zipf-ish unigram mixture with short-range Markov
structure (repeated n-grams), so cross-entropy actually *decreases* during
the example training runs instead of pinning at log(V).

For the modality-stub architectures (musicgen/paligemma) the pipeline emits
precomputed frame/patch embeddings per the assignment's input_specs contract.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2          # unigram skew
    markov_repeat: float = 0.35  # P(copy token from 8 positions back)


class SyntheticLM:
    """Stateless batch factory: ``batch(step, shard, num_shards)``."""

    def __init__(self, cfg: ArchConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data
        if data.global_batch % 1:
            raise ValueError("global_batch must be int")

    def _tokens(self, key, batch: int, seq: int) -> jax.Array:
        V = self.cfg.vocab_size
        k1, k2, k3 = jax.random.split(key, 3)
        # Zipf-ish unigram over a 4096-symbol active set (cheap on host)
        active = min(V, 4096)
        ranks = jnp.arange(1, active + 1, dtype=jnp.float32)
        probs = ranks ** -self.data.zipf_a
        probs = probs / probs.sum()
        base = jax.random.choice(k1, active, (batch, seq), p=probs)
        # short-range repeats give learnable structure
        copy = jax.random.bernoulli(k2, self.data.markov_repeat,
                                    (batch, seq))
        shifted = jnp.roll(base, 8, axis=1)
        toks = jnp.where(copy, shifted, base)
        return toks.astype(jnp.int32)

    def batch(self, step: int, shard: int = 0, num_shards: int = 1) -> dict:
        """Host-side: returns numpy-backed arrays for one DP shard."""
        d, cfg = self.data, self.cfg
        assert d.global_batch % num_shards == 0
        b = d.global_batch // num_shards
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(d.seed), step), shard)
        S = d.seq_len
        out: dict = {}
        if cfg.input_mode == "tokens":
            toks = self._tokens(key, b, S + 1)
            out["tokens"] = toks[:, :-1]
            out["labels"] = toks[:, 1:]
        elif cfg.input_mode == "embeddings":
            k1, k2 = jax.random.split(key)
            out["embeddings"] = jax.random.normal(
                k1, (b, S, cfg.d_model), jnp.float32) * 0.02
            out["labels"] = self._tokens(k2, b, S)
        elif cfg.input_mode == "prefix_embeddings":
            k1, k2 = jax.random.split(key)
            s_text = S - cfg.prefix_len
            toks = self._tokens(k2, b, s_text + 1)
            out["prefix_embeddings"] = jax.random.normal(
                k1, (b, cfg.prefix_len, cfg.d_model), jnp.float32) * 0.02
            out["tokens"] = toks[:, :-1]
            out["labels"] = toks[:, 1:]
        else:
            raise ValueError(cfg.input_mode)
        return out


def make_batch_specs(cfg: ArchConfig, seq_len: int,
                     global_batch: int) -> dict:
    """ShapeDtypeStruct stand-ins for one *global* training batch — the
    dry-run contract (no allocation)."""
    B, S = global_batch, seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    if cfg.input_mode == "tokens":
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32)}
    if cfg.input_mode == "embeddings":
        return {"embeddings": jax.ShapeDtypeStruct((B, S, cfg.d_model), f32),
                "labels": jax.ShapeDtypeStruct((B, S), i32)}
    if cfg.input_mode == "prefix_embeddings":
        s_text = S - cfg.prefix_len
        return {
            "prefix_embeddings": jax.ShapeDtypeStruct(
                (B, cfg.prefix_len, cfg.d_model), f32),
            "tokens": jax.ShapeDtypeStruct((B, s_text), i32),
            "labels": jax.ShapeDtypeStruct((B, s_text), i32)}
    raise ValueError(cfg.input_mode)
