from repro.models.model import (decode_step, init_params, prefill_forward,
                                train_forward, forward_hidden, LayerCache)  # noqa: F401
