"""Decoder LM supporting every assigned architecture family.

Execution model
---------------
Layer parameters are *stacked* over the layer axis and executed with
``lax.scan`` (small HLO, fast multi-pod compiles).  Architectures whose
attention kind varies per layer are handled without dynamic branching:

  * ``local_global_period = p`` (gemma2): one scan over L/p steps whose body
    unrolls the p sublayers with static window kinds (position p-1 global);
  * explicit ``global_layers`` (hymba): the layer axis is segmented into
    *runs* — singleton global layers unrolled, local stretches scanned.

Three entry points:
  * :func:`train_forward`  -- full-seq forward + chunked cross-entropy loss;
  * :func:`prefill_forward` -- full-seq forward returning per-layer KV (and
    SSM state) caches for the serving layer;
  * :func:`decode_step`    -- one-token forward over materialized per-layer
    contexts (paged-gather or shortcut-contiguous, chosen by the caller).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (embed_init, grad_bf16, mlp_apply,
                                 mlp_init, rms_norm, softcap)


# -- layer kinds / runs --------------------------------------------------------

def layer_kinds(cfg: ArchConfig) -> list[str]:
    """'global' (full causal) or 'local' (sliding window) per layer."""
    L = cfg.num_layers
    if cfg.sliding_window is None:
        return ["global"] * L
    if cfg.local_global_period:
        p = cfg.local_global_period
        return ["global" if i % p == p - 1 else "local" for i in range(L)]
    if cfg.global_layers:
        return ["global" if i in cfg.global_layers else "local"
                for i in range(L)]
    return ["local"] * L


def layer_runs(cfg: ArchConfig) -> list[tuple[int, int, tuple[str, ...]]]:
    """(start, length, kinds-per-step) segments executable as one scan."""
    kinds = layer_kinds(cfg)
    L = cfg.num_layers
    p = cfg.local_global_period
    if p and L % p == 0:
        return [(0, L, tuple(kinds[:p]))]
    runs: list[tuple[int, int, tuple[str, ...]]] = []
    i = 0
    while i < L:
        j = i
        while j < L and kinds[j] == kinds[i]:
            j += 1
        runs.append((i, j - i, (kinds[i],)))
        i = j
    return runs


# -- init ----------------------------------------------------------------------

def layer_init(key, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.has_attention:
        p["attn"] = attn.attn_init(ks[0], cfg, dtype)
    if cfg.has_ssm:
        p["ssm"] = ssm_mod.ssm_init(ks[1], cfg, dtype)
    if cfg.d_ff or cfg.num_experts:
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
    if cfg.d_ff:
        p["mlp"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype)
    if cfg.num_experts:
        p["moe"] = moe_mod.moe_init(ks[3], cfg, dtype)
    return p


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> dict:
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    params = {
        "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype),
        "layers": jax.vmap(
            lambda k: layer_init(k, cfg, dtype))(layer_keys),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(
            k_head, cfg.vocab_size, cfg.d_model, dtype).T
    return params


# -- sublayer bodies -------------------------------------------------------------

class LayerCache(NamedTuple):
    """Per-layer decode cache pieces produced by prefill (stacked over L by
    the caller).  Unused members are () placeholders to keep pytrees static."""
    k: Any = ()
    v: Any = ()
    ssm: Any = ()


def _mixer(lp: dict, h: jax.Array, cfg: ArchConfig, kind: str,
           positions: jax.Array, want_cache: bool):
    """Attention and/or SSM branch on pre-normed input (full sequence)."""
    x = rms_norm(h, lp["ln1"], cfg.norm_eps)
    outs = []
    cache = LayerCache()
    if cfg.has_attention:
        q, k, v = attn.qkv_project(lp["attn"], x, cfg, positions)
        # mesh-divisibility head padding (see ArchConfig.pad_*): zero
        # q-heads / kv-groups so the flat head count divides the model
        # axis -> clean head-parallel attention instead of the f32
        # score all-reduces GSPMD emits for fractional-head layouts
        q, k, v, n_heads = attn.pad_heads(q, k, v, cfg)
        # pin head-logical sharding (a no-op when not divisible)
        q = constrain(q, ("batch", None, "heads", None))
        k = constrain(k, ("batch", None, "kv_heads", None))
        v = constrain(v, ("batch", None, "kv_heads", None))
        window = cfg.sliding_window if kind == "local" else None
        o = attn.blockwise_attention(
            q, k, v, chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
            causal=True, window=window, attn_softcap=cfg.attn_softcap,
            prefix_len=cfg.prefix_len)
        B, S = x.shape[:2]
        o = constrain(o, ("batch", None, "heads", None))
        o = attn.unpad_heads(o, cfg)
        o = o.reshape(B, S, -1) @ lp["attn"]["wo"]
        outs.append(o)
        if want_cache:
            cache = cache._replace(k=k, v=v)
    if cfg.has_ssm:
        o, ssm_cache = ssm_mod.ssm_apply(lp["ssm"], x, cfg)
        outs.append(o)
        if want_cache:
            cache = cache._replace(ssm=ssm_cache)
    mix = outs[0] if len(outs) == 1 else (outs[0] + outs[1]) * 0.5
    return h + grad_bf16(mix), cache


def _ffn(lp: dict, h: jax.Array, cfg: ArchConfig):
    """MLP / MoE (+ optional arctic-style parallel dense residual)."""
    if not (cfg.d_ff or cfg.num_experts):
        return h, jnp.zeros((), jnp.float32)
    x = rms_norm(h, lp["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    out = 0.0
    if cfg.num_experts:
        mo, aux = moe_mod.moe_apply(lp["moe"], x, cfg)
        out = out + mo
        if cfg.dense_residual and cfg.d_ff:
            out = out + mlp_apply(lp["mlp"], x, cfg.act)
    elif cfg.d_ff:
        out = out + mlp_apply(lp["mlp"], x, cfg.act)
    return h + grad_bf16(out), aux


def _sublayer_full(lp, h, cfg, kind, positions, want_cache):
    # pin activations to DP sharding inside the scanned body — without this
    # GSPMD has been observed to replicate the batch dim across the mesh for
    # the attention einsums (16x the per-device FLOPs)
    h = constrain(h, ("batch", None, None))
    h, cache = _mixer(lp, h, cfg, kind, positions, want_cache)
    h, aux = _ffn(lp, h, cfg)
    return h, cache, aux


# -- full-sequence forward -------------------------------------------------------

def _slice_layers(layers, start: int, length: int):
    return jax.tree.map(
        lambda a: jax.lax.slice_in_dim(a, start, start + length, axis=0),
        layers)


def _embed_inputs(params, cfg: ArchConfig, batch: dict) -> jax.Array:
    if cfg.input_mode == "tokens":
        h = params["embed"][batch["tokens"]]
    elif cfg.input_mode == "embeddings":
        h = batch["embeddings"].astype(params["embed"].dtype)
    elif cfg.input_mode == "prefix_embeddings":
        tok = params["embed"][batch["tokens"]]
        h = jnp.concatenate(
            [batch["prefix_embeddings"].astype(tok.dtype), tok], axis=1)
    else:
        raise ValueError(cfg.input_mode)
    return h


def forward_hidden(params, cfg: ArchConfig, batch: dict, *,
                   want_cache: bool = False,
                   remat: bool = True):
    """Embed + all layers.  Returns (hidden (B,S,D), caches, aux_loss)."""
    h = _embed_inputs(params, cfg, batch)
    h = constrain(h, ("batch", None, None))
    B, S = h.shape[:2]
    positions = jnp.arange(S)[None]
    aux_total = jnp.zeros((), jnp.float32)
    caches = []

    for start, length, kinds in layer_runs(cfg):
        p = len(kinds)
        run_layers = _slice_layers(params["layers"], start, length)
        if length == p:  # singleton (or one full period): run inline
            sub = jax.tree.map(lambda a: a, run_layers)
            for j, kind in enumerate(kinds):
                lp = jax.tree.map(lambda a: a[j], sub)
                h, cache, aux = _sublayer_full(
                    lp, h, cfg, kind, positions, want_cache)
                aux_total += aux
                caches.append(jax.tree.map(lambda a: a[None] if hasattr(
                    a, "ndim") else a, cache))
            continue

        steps = length // p
        stacked = jax.tree.map(
            lambda a: a.reshape((steps, p) + a.shape[1:]), run_layers)

        def body(carry, step_layers, kinds=kinds, p=p):
            h, aux_total = carry
            step_caches = []
            for j in range(p):
                lp = jax.tree.map(lambda a: a[j], step_layers)
                h, cache, aux = _sublayer_full(
                    lp, h, cfg, kinds[j], positions, want_cache)
                aux_total += aux
                step_caches.append(cache)
            out_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *step_caches) \
                if p > 1 else step_caches[0]
            return (h, aux_total), out_cache

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable,
                prevent_cse=False)
        (h, aux_total), run_caches = jax.lax.scan(
            body, (h, aux_total), stacked)
        if want_cache:
            caches.append(jax.tree.map(
                lambda a: a.reshape((length,) + a.shape[2:])
                if p > 1 and hasattr(a, "ndim") else a, run_caches))

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    cache_stack = None
    if want_cache:
        cache_stack = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *caches)
    return h, cache_stack, aux_total


def _logits(params, cfg: ArchConfig, h: jax.Array) -> jax.Array:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    out = h @ head
    # keep logits vocab-sharded: without this pin GSPMD gathers the full
    # (B, ..., V) per device, which dominates temp memory and collectives
    out = constrain(out, ("batch",) + (None,) * (out.ndim - 2) + ("vocab",))
    if cfg.final_softcap:
        out = softcap(out, cfg.final_softcap)
    return out


def chunked_softmax_xent(params, cfg: ArchConfig, h: jax.Array,
                         labels: jax.Array, mask: jax.Array) -> jax.Array:
    """Cross-entropy without materializing (B, S, V) at once."""
    B, S, D = h.shape
    chunk = min(cfg.loss_chunk, S)
    if S % chunk:
        chunk = S
    n = S // chunk

    def body(carry, xs):
        hc, lc, mc = xs
        logits = _logits(params, cfg, hc).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via masked reduction: stays vocab-sharded (a gather
        # on the sharded axis would force an all-gather of the logits)
        vocab_iota = jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, logits.ndim - 1)
        gold = jnp.where(vocab_iota == lc[..., None], logits, 0.0).sum(-1)
        nll = (lse - gold) * mc
        return carry + nll.sum(), None

    xs = (h.reshape(B, n, chunk, D).swapaxes(0, 1),
          labels.reshape(B, n, chunk).swapaxes(0, 1),
          mask.reshape(B, n, chunk).swapaxes(0, 1))
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs)
    return total / jnp.maximum(mask.sum(), 1.0)


def train_forward(params, cfg: ArchConfig, batch: dict, *,
                  remat: bool = True) -> jax.Array:
    """Returns scalar loss.  batch: tokens/embeddings (+labels, loss_mask)."""
    h, _, aux = forward_hidden(params, cfg, batch, want_cache=False,
                               remat=remat)
    labels = batch["labels"]
    if cfg.input_mode == "prefix_embeddings":  # loss only on the suffix
        h = h[:, cfg.prefix_len:]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    loss = chunked_softmax_xent(params, cfg, h, labels, mask)
    return loss + cfg.router_aux_weight * aux


# -- prefill -----------------------------------------------------------------

def prefill_forward(params, cfg: ArchConfig, batch: dict):
    """Full forward returning (last-position logits, stacked caches)."""
    h, caches, _ = forward_hidden(params, cfg, batch, want_cache=True,
                                  remat=False)
    logits = _logits(params, cfg, h[:, -1])
    return logits, caches


# -- decode --------------------------------------------------------------------

def decode_step(params, cfg: ArchConfig, token: jax.Array,
                ctx: LayerCache, ctx_len: jax.Array):
    """One-token decode.

    token:   (B,) int32 current input token.
    ctx:     stacked per-layer contexts —
               k/v: (L, B, S_ctx, KV, hd) *materialized* context (old tokens
               live in [0, ctx_len-1)); ssm: SSMCache stacked over L.
    ctx_len: (B,) int32 context length INCLUDING the new token.

    Returns (logits (B, V), new_kv (L, B, KV, hd) pair or (), new_ssm).
    The caller appends new_kv into its pool (paged) or view (shortcut).
    """
    h = params["embed"][token][:, None]                   # (B, 1, D)
    positions = (ctx_len - 1)[:, None]
    kinds = layer_kinds(cfg)
    B = token.shape[0]

    def one_layer(h, lp, kind, ctx_l):
        x = rms_norm(h, lp["ln1"], cfg.norm_eps)
        outs = []
        new_k = new_v = ()
        new_ssm = ()
        if cfg.has_attention:
            q, k, v = attn.qkv_project(lp["attn"], x, cfg, positions)
            window = cfg.sliding_window if kind == "local" else None
            o = attn.decode_attention(
                q[:, 0], ctx_l.k, ctx_l.v, ctx_len,
                k_new=k[:, 0], v_new=v[:, 0],
                attn_softcap=cfg.attn_softcap, window=window)
            outs.append((o.reshape(B, -1) @ lp["attn"]["wo"])[:, None])
            new_k, new_v = k[:, 0], v[:, 0]
        if cfg.has_ssm:
            o, new_ssm = ssm_mod.ssm_decode(lp["ssm"], x[:, 0], ctx_l.ssm,
                                            cfg)
            outs.append(o[:, None])
        mix = outs[0] if len(outs) == 1 else (outs[0] + outs[1]) * 0.5
        h = h + mix
        h, _ = _ffn(lp, h, cfg)
        return h, LayerCache(k=new_k, v=new_v, ssm=new_ssm)

    # segment the scan exactly like the full forward
    news = []
    for start, length, run_kinds in layer_runs(cfg):
        p = len(run_kinds)
        run_layers = _slice_layers(params["layers"], start, length)
        run_ctx = jax.tree.map(
            lambda a: jax.lax.slice_in_dim(a, start, start + length, axis=0)
            if hasattr(a, "ndim") else a, ctx)
        if length == p:
            for j, kind in enumerate(run_kinds):
                lp = jax.tree.map(lambda a: a[j], run_layers)
                cl = jax.tree.map(lambda a: a[j] if hasattr(a, "ndim") else a,
                                  run_ctx)
                h, new = one_layer(h, lp, kind, cl)
                news.append(jax.tree.map(
                    lambda a: a[None] if hasattr(a, "ndim") else a, new))
            continue
        steps = length // p
        stacked = jax.tree.map(
            lambda a: a.reshape((steps, p) + a.shape[1:]), run_layers)
        stacked_ctx = jax.tree.map(
            lambda a: a.reshape((steps, p) + a.shape[1:])
            if hasattr(a, "ndim") else a, run_ctx)

        def body(h, xs, run_kinds=run_kinds, p=p):
            step_layers, step_ctx = xs
            step_news = []
            for j in range(p):
                lp = jax.tree.map(lambda a: a[j], step_layers)
                cl = jax.tree.map(lambda a: a[j] if hasattr(a, "ndim")
                                  else a, step_ctx)
                h, new = one_layer(h, lp, run_kinds[j], cl)
                step_news.append(new)
            out = jax.tree.map(lambda *xs: jnp.stack(xs), *step_news) \
                if p > 1 else step_news[0]
            return h, out

        h, run_news = jax.lax.scan(body, h, (stacked, stacked_ctx))
        news.append(jax.tree.map(
            lambda a: a.reshape((length,) + a.shape[2:])
            if p > 1 and hasattr(a, "ndim") else a, run_news))

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, h[:, 0])
    new_stack = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *news)
    return logits, new_stack
