"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

Dispatch strategy (TPU-friendly, static shapes):
  1. top-k expert choice per token, router weights renormalized;
  2. the (token, choice) pairs are sorted by expert id;
  3. each expert segment keeps its first ``capacity`` tokens (standard
     capacity-factor dropping), scattered to a dense ``(E, C, D)`` buffer;
  4. two grouped einsums run the expert FFNs;
  5. results scatter-add back with router weights.

The ``(E, C, *)`` buffers carry the "expert" logical axis, so expert
parallelism is pure sharding (XLA inserts the all-to-alls).  Supports shared
experts (qwen2-moe) and a parallel dense residual branch (arctic).

Aux loss: switch-style load-balancing loss (mean fraction * mean prob * E).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import activation, mlp_apply, mlp_init, _dense_init


def moe_init(key, cfg, dtype) -> dict:
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    p = {
        "router": _dense_init(ks[0], (d, e), d, jnp.float32),
        "wi": _dense_init(ks[1], (e, d, 2 * ff), d, dtype),
        "wo": _dense_init(ks[2], (e, ff, d), ff, dtype),
    }
    if cfg.shared_d_ff:
        p["shared"] = mlp_init(ks[3], d, cfg.shared_d_ff, dtype)
    return p



# ---------------------------------------------------------------------------
# Permutation-dual gathers.
#
# Every tensor movement in the dispatch is a (batched) gather, which GSPMD
# partitions on the batch axis — but autodiff turns a gather's backward
# into a scatter-add, which GSPMD replicates (measured: the f32
# (B, S*K, D) scatter cotangents re-replicated the arctic-480b cell).
# Because the dispatch mappings are *permutations with known inverses*,
# each backward is itself expressible as a gather; these custom VJPs keep
# fwd AND bwd in partitionable gather form.
# ---------------------------------------------------------------------------

import jax as _jax


@_jax.custom_vjp
def _gather_tokens(x, stok):
    """(B,S,D),(B,S*K) -> (B,S*K,D): xg[b,j] = x[b, stok[b,j]]."""
    return jnp.take_along_axis(x, stok[..., None], axis=1)


def _gather_tokens_fwd(x, stok):
    return _gather_tokens(x, stok), (stok, x.shape[1])


def _gather_tokens_bwd(res, ct):
    stok, S = res
    B, SK, D = ct.shape
    K = SK // S
    # stok holds each token id exactly K times; stable argsort groups the
    # K occurrences of token t at rows [t*K, (t+1)*K)
    inv = jnp.argsort(stok, axis=-1)
    g = jnp.take_along_axis(ct, inv[..., None], axis=1)
    return g.reshape(B, S, K, D).sum(axis=2), None


_gather_tokens.defvjp(_gather_tokens_fwd, _gather_tokens_bwd)


@_jax.custom_vjp
def _pairs_to_slots(xg, src, hit, slot, keep):
    """(B,S*K,D) pairs -> (B,E*C,D) buffer rows: buf[t] = xg[src[t]]*hit.

    Inverse mapping (slot, keep): pair p fills target slot[p] iff keep[p].
    """
    g = jnp.take_along_axis(xg, src[..., None], axis=1)
    return g * hit[..., None].astype(g.dtype)


def _pairs_to_slots_fwd(xg, src, hit, slot, keep):
    return _pairs_to_slots(xg, src, hit, slot, keep), (slot, keep)


def _pairs_to_slots_bwd(res, ct):
    slot, keep = res
    safe = jnp.minimum(slot, ct.shape[1] - 1)
    g = jnp.take_along_axis(ct, safe[..., None], axis=1)
    return g * keep[..., None].astype(g.dtype), None, None, None, None


_pairs_to_slots.defvjp(_pairs_to_slots_fwd, _pairs_to_slots_bwd)


@_jax.custom_vjp
def _slots_to_pairs(out_flat, slot, keep, src, hit):
    """(B,E*C,D) buffer -> (B,S*K,D) pairs: y[p] = out_flat[slot[p]]*keep;
    the exact inverse of :func:`_pairs_to_slots`."""
    safe = jnp.minimum(slot, out_flat.shape[1] - 1)
    g = jnp.take_along_axis(out_flat, safe[..., None], axis=1)
    return g * keep[..., None].astype(g.dtype)


def _slots_to_pairs_fwd(out_flat, slot, keep, src, hit):
    return _slots_to_pairs(out_flat, slot, keep, src, hit), (src, hit)


def _slots_to_pairs_bwd(res, ct):
    src, hit = res
    g = jnp.take_along_axis(ct, src[..., None], axis=1)
    return (g * hit[..., None].astype(g.dtype), None, None, None, None)


_slots_to_pairs.defvjp(_slots_to_pairs_fwd, _slots_to_pairs_bwd)


def moe_capacity(cfg, tokens: int) -> int:
    """Capacity per dispatch group (a batch row: ``tokens`` = seq_len)."""
    cap = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, -(-cap // 8) * 8)  # round up to 8 for tiling


def moe_apply(p: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B,S,D), aux_loss ()).

    Dispatch is PER BATCH ROW (sort/capacity/scatter all operate along the
    sequence axis), so under data parallelism every step is shard-local by
    construction and the only cross-device traffic is the canonical MoE
    all-to-all that moves the (B, E, C, D) buffer between the batch and
    expert shardings.  A global-sort dispatch (previous revision) forced
    GSPMD into a distributed argsort — ~50x the collective bytes on the
    arctic-480b dry-run (EXPERIMENTS.md §Perf iteration 1).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    C = moe_capacity(cfg, S)                                 # per row
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"])                         # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, K)                   # (B, S, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (computed before dropping)
    frac = jnp.mean(
        jax.nn.one_hot(top_i, E, dtype=jnp.float32).sum(2), axis=(0, 1))
    aux = E * jnp.sum(frac * probs.mean((0, 1))) / K

    # per-row sort of (token, choice) pairs by expert id
    flat_e = top_i.reshape(B, S * K)
    flat_w = top_w.reshape(B, S * K)
    tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)[None], (B, S * K))
    order = jnp.argsort(flat_e, axis=-1)                     # local sort
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    stok = jnp.take_along_axis(tok, order, axis=-1)
    sw = jnp.take_along_axis(flat_w, order, axis=-1)
    # rank within each expert segment; drop ranks >= capacity
    seg_start = jax.vmap(
        lambda a: jnp.searchsorted(a, a, side="left"))(se)
    rank = jnp.arange(S * K, dtype=jnp.int32)[None] - seg_start
    keep = rank < C
    slot = jnp.where(keep, se * C + rank, E * C)             # E*C = drop

    # ALL data movement below is along-axis (gather/scatter with leading
    # batch dims) or pure permutation — forms GSPMD partitions on the
    # batch axis without replication (explicit 2-D scatter indices do NOT
    # partition and forced full replication of (B, S*K, D) tensors)
    # build the (E*C)-slot buffer with GATHERS ONLY: GSPMD partitions
    # along-axis gathers on the batch dim but replicates every scatter
    # form we tried (measured: .at[b,i].set and vmapped row scatters each
    # force an all-gather of the (B, S*K, D) operand)
    ord2 = jnp.argsort(slot, axis=-1)                        # by target
    sorted_slots = jnp.take_along_axis(slot, ord2, axis=-1)
    targets = jnp.broadcast_to(jnp.arange(E * C, dtype=jnp.int32)[None],
                               (B, E * C))
    j = jax.vmap(jnp.searchsorted)(sorted_slots, targets)    # (B, E*C)
    j = jnp.minimum(j, S * K - 1)
    hit = jnp.take_along_axis(sorted_slots, j, axis=-1) == targets
    src = jnp.take_along_axis(ord2, j, axis=-1)              # source pair
    xg = _gather_tokens(x, stok)                             # (B, S*K, D)
    buf = _pairs_to_slots(xg, src, hit, slot, keep)          # local
    # dispatch boundary: everything above is shard-local on the batch
    # axis with E replicated; the pin below slices E onto the model axis
    # (free forward; the backward is ONE bf16 all-gather per layer instead
    # of the f32 all-reduces GSPMD emits for cross-shard gathers)
    buf = constrain(buf.astype(x.dtype).reshape(B, E, C, D),
                    ("batch", "expert", None, None))

    h = jnp.einsum("becd,edf->becf", buf, p["wi"])           # (B,E,C,2ff)
    gate, up = jnp.split(h, 2, axis=-1)
    h = activation(gate, cfg.act) * up
    out_e = jnp.einsum("becf,efd->becd", h, p["wo"])
    out_e = constrain(out_e, ("batch", "expert", None, None))
    # combine boundary: explicit bf16 all-gather of the expert outputs
    # back to E-replicated so the pair gather below is shard-local
    out_flat = constrain(out_e.reshape(B, E * C, D),
                         ("batch", None, None))

    contrib = _slots_to_pairs(out_flat, slot, keep, src, hit)
    contrib = contrib * sw.astype(x.dtype)[..., None]
    # combine WITHOUT a scatter-add: un-sort via the inverse permutation,
    # then the K choices of each token are adjacent -> sum over K
    inv = jnp.argsort(order, axis=-1)
    contrib = jnp.take_along_axis(contrib, inv[..., None], axis=1)
    out = contrib.reshape(B, S, K, D).sum(axis=2)

    if "shared" in p:
        out = out + mlp_apply(p["shared"], x, cfg.act)
    return out, aux
