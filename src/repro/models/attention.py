"""Grouped-query attention with blockwise (flash-style) computation.

The training/prefill path is *triangular-blockwise*: an unrolled loop over
query tiles, each running a ``lax.scan`` over only the key/value tiles its
causal (and sliding-window) footprint touches — so compiled FLOPs match the
causal workload instead of doubling through a full masked product, and peak
memory stays O(tile) instead of O(S^2).  This is also the jnp oracle for the
Pallas ``flash_attention`` kernel.

Supports: GQA/MQA, rope, qk-norm (qwen3), attention logit softcap (gemma2),
sliding windows + per-layer local/global switching (gemma2, hymba).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, rms_norm, softcap

_NEG_INF = -1e30


def attn_init(key, cfg, dtype) -> dict:
    from repro.models.layers import _dense_init
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, h * hd), d, dtype),
        "wk": _dense_init(ks[1], (d, kv * hd), d, dtype),
        "wv": _dense_init(ks[2], (d, kv * hd), d, dtype),
        "wo": _dense_init(ks[3], (h * hd, d), h * hd, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def qkv_project(p: dict, x: jax.Array, cfg, positions: jax.Array):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,KV,hd), rope applied."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.num_heads, hd)
    k = (x @ p["wk"]).reshape(B, S, cfg.num_kv_heads, hd)
    v = (x @ p["wv"]).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def pad_heads(q, k, v, cfg):
    """Zero-pad q-groups / kv-heads to mesh-divisible counts.

    q: (B,S,H,hd) with H = KV*G -> (B,S,KVp*Gp,hd); k/v: (B,S,KV,hd) ->
    (B,S,KVp,hd).  Dead q-heads project zeros (scores 0 -> their outputs
    are discarded by :func:`unpad_heads` before wo); dead kv-heads form
    whole dead groups, so live outputs are bit-identical."""
    KV = cfg.num_kv_heads
    G = cfg.num_heads // KV
    KVp = cfg.pad_kv_heads or KV
    Gp = cfg.pad_q_groups or G
    if (KVp, Gp) == (KV, G):
        return q, k, v, cfg.num_heads
    B, S, H, hd = q.shape
    qg = q.reshape(B, S, KV, G, hd)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, KVp - KV), (0, Gp - G),
                      (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, 0), (0, KVp - KV), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, KVp - KV), (0, 0)))
    return qg.reshape(B, S, KVp * Gp, hd), k, v, KVp * Gp


def unpad_heads(o, cfg):
    """Drop dead heads: (B,S,KVp*Gp,hd) -> (B,S,H,hd)."""
    KV = cfg.num_kv_heads
    G = cfg.num_heads // KV
    KVp = cfg.pad_kv_heads or KV
    Gp = cfg.pad_q_groups or G
    if (KVp, Gp) == (KV, G):
        return o
    B, S, Hp, hd = o.shape
    og = o.reshape(B, S, KVp, Gp, hd)[:, :, :KV, :G]
    return og.reshape(B, S, KV * G, hd)


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        chunk_q: int, chunk_kv: int, causal: bool = True,
                        window: Optional[int] = None,
                        attn_softcap: Optional[float] = None,
                        prefix_len: int = 0) -> jax.Array:
    """Flash-style attention.  q: (B,Sq,H,hd), k/v: (B,Skv,KV,hd).

    ``prefix_len`` marks a bidirectional prefix (PaliGemma image tokens):
    positions < prefix_len attend to the whole prefix regardless of order.
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    if Sq % chunk_q:
        chunk_q = Sq
    if Skv % chunk_kv:
        chunk_kv = Skv
    scale = hd ** -0.5
    qg = q.reshape(B, Sq, KV, G, hd) * scale
    n_q = Sq // chunk_q
    outs = []
    for i in range(n_q):  # unrolled: static tile footprints
        q_blk = jax.lax.dynamic_slice_in_dim(qg, i * chunk_q, chunk_q, 1)
        q_pos = i * chunk_q + jnp.arange(chunk_q)
        # static kv footprint of this tile
        hi = min(Skv, (i + 1) * chunk_q) if causal else Skv
        hi = math.ceil(hi / chunk_kv) * chunk_kv
        lo = 0
        if window is not None and causal:
            lo = max(0, (i * chunk_q - window)) // chunk_kv * chunk_kv
            if prefix_len:
                lo = 0  # prefix is always visible
        n_kv = (hi - lo) // chunk_kv
        k_tiles = jax.lax.dynamic_slice_in_dim(k, lo, hi - lo, 1) \
            .reshape(B, n_kv, chunk_kv, KV, hd).transpose(1, 0, 2, 3, 4)
        v_tiles = jax.lax.dynamic_slice_in_dim(v, lo, hi - lo, 1) \
            .reshape(B, n_kv, chunk_kv, KV, hd).transpose(1, 0, 2, 3, 4)
        kv_pos = lo + jnp.arange(n_kv * chunk_kv).reshape(n_kv, chunk_kv)

        def step(carry, tile):
            m, l, acc = carry
            kt, vt, kp = tile
            s = jnp.einsum("bqkgd,bckd->bkgqc", q_blk, kt,
                           preferred_element_type=jnp.float32)
            if attn_softcap is not None:
                s = softcap(s, attn_softcap)
            mask = jnp.ones((chunk_q, chunk_kv), jnp.bool_)
            if causal:
                cm = q_pos[:, None] >= kp[None, :]
                if prefix_len:
                    cm = cm | ((q_pos[:, None] < prefix_len)
                               & (kp[None, :] < prefix_len))
                mask &= cm
            if window is not None:
                wm = kp[None, :] > (q_pos[:, None] - window)
                if prefix_len:
                    wm = wm | (kp[None, :] < prefix_len)
                mask &= wm
            s = jnp.where(mask[None, None, None], s, _NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bckd->bkgqd", p.astype(vt.dtype), vt,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        def tile_attention(q_blk, k_tiles, v_tiles):
            """One q-tile's full kv sweep.  Rematerialized as a unit: the
            backward recomputes the O(chunk_q x S) score tiles from q/k/v
            instead of stashing them per scan step — flash-attention
            backward economics (2x attention FLOPs, O(tile) memory)."""
            init = (jnp.full((B, KV, G, chunk_q), _NEG_INF, jnp.float32),
                    jnp.zeros((B, KV, G, chunk_q), jnp.float32),
                    jnp.zeros((B, KV, G, chunk_q, hd), jnp.float32))
            (m, l, acc), _ = jax.lax.scan(
                step, init, (k_tiles, v_tiles, kv_pos))
            return acc / jnp.maximum(l, 1e-30)[..., None]

        o = jax.checkpoint(
            tile_attention,
            policy=jax.checkpoint_policies.nothing_saveable,
            prevent_cse=False)(q_blk, k_tiles, v_tiles)
        outs.append(o.transpose(0, 3, 1, 2, 4).reshape(B, chunk_q, H, hd))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def decode_attention(q: jax.Array, k_ctx: jax.Array, v_ctx: jax.Array,
                     ctx_len: jax.Array, *,
                     k_new: Optional[jax.Array] = None,
                     v_new: Optional[jax.Array] = None,
                     attn_softcap: Optional[float] = None,
                     window: Optional[int] = None) -> jax.Array:
    """One-token attention over a materialized context.

    q: (B, H, hd); k_ctx/v_ctx: (B, KV, S, hd) (attention-native layout)
    hold the *old* tokens at positions [0, ctx_len-1).  ``k_new``/``v_new``
    (B, KV, hd) are the current token's projections, folded in by split
    softmax (``ctx_len`` counts the new token).
    """
    B, H, hd = q.shape
    KV, S = k_ctx.shape[1], k_ctx.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd) * hd ** -0.5
    s = jnp.einsum("bkgd,bksd->bkgs", qg, k_ctx,
                   preferred_element_type=jnp.float32)
    pos = jnp.arange(S)[None]                        # (1, S)
    n_old = ctx_len[:, None] - (0 if k_new is None else 1)
    live = pos < n_old
    if window is not None:
        live &= pos > (ctx_len[:, None] - 1 - window)
    if attn_softcap is not None:
        s = softcap(s, attn_softcap)
    s = jnp.where(live[:, None, None], s, _NEG_INF)
    if k_new is None:
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgs,bksd->bkgd", p.astype(v_ctx.dtype), v_ctx,
                       preferred_element_type=jnp.float32)
        return o.reshape(B, H, hd).astype(q.dtype)
    # the current token's kv is handled by SPLIT softmax algebra instead
    # of concatenating a (B, S+1, KV, hd) copy of the value cache — the
    # concat cost a full extra cache read+write per layer per step
    # (EXPERIMENTS.md §Perf, decode hillclimb)
    s_self = jnp.einsum("bkgd,bkd->bkg", qg, k_new,
                        preferred_element_type=jnp.float32)
    if attn_softcap is not None:
        s_self = softcap(s_self, attn_softcap)
    m = jnp.maximum(s.max(axis=-1), s_self)         # (B, KV, G)
    p_ctx = jnp.exp(s - m[..., None])
    p_self = jnp.exp(s_self - m)
    denom = p_ctx.sum(axis=-1) + p_self
    o = jnp.einsum("bkgs,bksd->bkgd", p_ctx.astype(v_ctx.dtype), v_ctx,
                   preferred_element_type=jnp.float32)
    o = (o + p_self[..., None] * v_new[:, :, None].astype(jnp.float32)
         ) / denom[..., None]
    return o.reshape(B, H, hd).astype(q.dtype)
