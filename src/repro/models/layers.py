"""Shared neural-net building blocks (pure functions over param pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def activation(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(kind)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(x / cap)


@jax.custom_vjp
def grad_bf16(x: jax.Array) -> jax.Array:
    """Identity whose COTANGENT is rounded to bf16.

    Placed at the mixer/FFN branch outputs so the tensor-parallel backward
    all-reduces (and the MoE dispatch backward gathers) carry bf16
    payloads instead of f32 — standard mixed-precision gradient practice,
    halving the dominant collective volume (EXPERIMENTS.md §Perf)."""
    return x


def _grad_bf16_fwd(x):
    return x, None


def _grad_bf16_bwd(_, ct):
    return (ct.astype(jnp.bfloat16).astype(ct.dtype),)


grad_bf16.defvjp(_grad_bf16_fwd, _grad_bf16_bwd)


# -- rotary position embedding ------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- MLP -----------------------------------------------------------------------

def mlp_apply(p: dict, x: jax.Array, act: str) -> jax.Array:
    """SwiGLU/GeGLU MLP; ``wi`` fuses gate and up projections."""
    h = x @ p["wi"]                                     # (..., 2*ff)
    gate, up = jnp.split(h, 2, axis=-1)
    return (activation(gate, act) * up) @ p["wo"]


def mlp_init(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "wi": _dense_init(k1, (d_model, 2 * d_ff), d_model, dtype),
        "wo": _dense_init(k2, (d_ff, d_model), d_ff, dtype),
    }


def _dense_init(key, shape, fan_in: int, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32)
            * (fan_in ** -0.5)).astype(dtype)


def embed_init(key, vocab: int, d_model: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d_model), jnp.float32)
            * 0.02).astype(dtype)
