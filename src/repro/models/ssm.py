"""Mamba2 / SSD (state-space duality) mixer, arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm — quadratic *within* fixed
chunks (MXU-friendly matmuls) plus a linear inter-chunk state recurrence —
so compute is O(S * chunk) and decode state is O(1): exactly why the ssm
and hybrid architectures keep the ``long_500k`` cell runnable.

Decode is the classic selective-scan single-step recurrence over
``(B, H, P, N)`` state plus a small causal-conv ring buffer.

Single B/C group (n_groups=1), as in the released mamba2 configs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init, rms_norm


class SSMCache(NamedTuple):
    """Decode-time state for one layer (stacked over layers by the runtime)."""
    conv: jax.Array   # (B, d_conv-1, d_inner + 2*N) rolling conv window
    state: jax.Array  # (B, H, P, N) SSM state


def ssm_init(key, cfg, dtype) -> dict:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    conv_dim = di + 2 * n
    ks = jax.random.split(key, 5)
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * di + 2 * n + h), d, dtype),
        "out_proj": _dense_init(ks[1], (di, d), di, dtype),
        "conv_w": _dense_init(ks[2], (cfg.ssm_conv, conv_dim), cfg.ssm_conv,
                              dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.zeros((di,), dtype),
    }


def _split_proj(cfg, zxbcdt: jax.Array):
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * n]
    dt = zxbcdt[..., di + di + 2 * n:di + di + 2 * n + h]
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds (d_conv is small: 4)."""
    d_conv = w.shape[0]
    out = xBC * w[-1]
    for j in range(1, d_conv):
        shifted = jnp.pad(xBC, ((0, 0), (j, 0), (0, 0)))[:, :-j]
        out = out + shifted * w[-1 - j]
    return jax.nn.silu(out + b)


def _segsum_chunk(dA: jax.Array):
    """Within-chunk cumulative sums used by SSD.  dA: (B, NC, Q, H)."""
    cs = jnp.cumsum(dA, axis=2)
    return cs


def ssd_chunked(x, dt, A, B, C, D, chunk: int):
    """SSD forward.  x: (B,S,H,P); dt: (B,S,H); A: (H,) negative;
    B,C: (B,S,N); D: (H,).  Returns (y: (B,S,H,P), final_state (B,H,P,N))."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:  # zero-pad: dt=0 makes padded steps identity/no-contribution
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    s_padded = s + pad
    nc = s_padded // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    dA = dtc * A                                       # (b,nc,q,h), negative
    cs = _segsum_chunk(dA)                             # cumulative within chunk

    # 1. intra-chunk (quadratic in chunk): Y_ij = C_i B_j^T L_ij dt_j x_j
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc,
                        preferred_element_type=jnp.float32)
    # mask the exponent BEFORE exp: upper-triangular entries are
    # exp(positive) -> inf, and where(tri, inf, 0) still propagates NaN
    # through the backward pass (0 * inf)
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
    delta = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # (b,nc,i,j,h)
    delta = jnp.where(tri[None, None, :, :, None], delta, -1e30)
    L = jnp.exp(delta)
    y_diag = jnp.einsum("bcij,bcijh,bcjh,bcjhp->bcihp",
                        scores, L, dtc, xc.astype(jnp.float32))

    # 2. per-chunk input state contribution
    chunk_sum = cs[:, :, -1, :]                        # (b,nc,h)
    decay_to_end = jnp.exp(chunk_sum[:, :, None, :] - cs)  # (b,nc,q,h)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn",
                        Bc, dtc * decay_to_end, xc.astype(jnp.float32))

    # 3. inter-chunk recurrence
    def step(carry, inp):
        st, da = inp                                   # (b,h,p,n), (b,h)
        new = carry * jnp.exp(da)[:, :, None, None] + st
        return new, carry                              # emit *entering* state

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_sum.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,nc,h,p,n)

    # 4. state -> output within chunk
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc, prev_states,
                       jnp.exp(cs))
    y = (y_diag + y_off).reshape(b, s_padded, h, p) + D[:, None] * x.astype(
        jnp.float32)
    return y[:, :s].astype(x.dtype), final


def ssm_apply(p: dict, x: jax.Array, cfg) -> tuple[jax.Array, SSMCache]:
    """Full-sequence (train/prefill) pass.  x: (B, S, D)."""
    B, S, _ = x.shape
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    z, xBC_raw, dt = _split_proj(cfg, x @ p["in_proj"])
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"])
    xs = xBC[..., :di].reshape(B, S, h, cfg.ssm_head_dim)
    Bm = xBC[..., di:di + n]
    Cm = xBC[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, final = ssd_chunked(xs, dt, A, Bm, Cm, p["D"], cfg.ssm_chunk)
    y = y.reshape(B, S, di) * jax.nn.silu(z.astype(jnp.float32)).astype(
        y.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    # decode conv window = the last d_conv-1 *pre-conv* xBC inputs
    dc = cfg.ssm_conv
    conv_tail = jnp.pad(xBC_raw, ((0, 0), (dc - 1, 0), (0, 0)))[:, S:, :]
    return out, SSMCache(conv=conv_tail, state=final)


def ssm_decode(p: dict, x: jax.Array, cache: SSMCache, cfg):
    """Single-token step.  x: (B, D) -> (out (B, D), new cache)."""
    B, _ = x.shape
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    z, xBC_t, dt = _split_proj(cfg, x @ p["in_proj"])
    window = jnp.concatenate([cache.conv, xBC_t[:, None]], axis=1)  # (B,dc,·)
    conv_out = jax.nn.silu(
        jnp.einsum("bjc,jc->bc", window.astype(jnp.float32),
                   p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(
            jnp.float32)).astype(x.dtype)
    xs = conv_out[..., :di].reshape(B, h, cfg.ssm_head_dim)
    Bm = conv_out[..., di:di + n]
    Cm = conv_out[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,h)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                         # (B,h)
    state = cache.state * dA[:, :, None, None] + jnp.einsum(
        "bn,bhp,bh->bhpn", Bm.astype(jnp.float32),
        xs.astype(jnp.float32), dt)
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), state) \
        + p["D"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(B, di).astype(x.dtype) * jax.nn.silu(
        z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], SSMCache(conv=window[:, 1:], state=state)


def ssm_cache_init(cfg, batch: int, dtype) -> SSMCache:
    di, n = cfg.ssm_d_inner, cfg.ssm_state
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * n), dtype),
        state=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                         cfg.ssm_state), jnp.float32),
    )
