import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh, derives in/out shardings from
the logical rules, lowers the appropriate step function against
ShapeDtypeStruct inputs (NO device allocation), compiles, and records:

  * memory analysis (bytes per device — proves the cell fits),
  * cost analysis  (per-device HLO FLOPs / bytes — roofline numerators),
  * collective stats parsed from the optimized HLO (bytes + op counts),
  * the three roofline terms (seconds) + the dominant bottleneck,
  * MODEL_FLOPS (6ND train / 2ND inference) and the useful-compute ratio.

Usage:
  python -m repro.launch.dryrun --arch qwen3_4b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --multi-pod both \
      --out results/dryrun
Options:
  --path shortcut|paged   decode access path (default shortcut; paged is the
                          traditional-directory baseline for §Perf)
  --opt  <key=val,...>    perf-iteration overrides (see OPTIMIZATIONS)
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base as cfg_base
from repro.configs.base import ArchConfig, get
from repro.data.pipeline import make_batch_specs
from repro.distributed import sharding as shd
from repro.launch import hlo_analysis as hlo
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, SKIP, cell_status, input_specs
from repro.optim.schedule import wsd_schedule
from repro.runtime import serve as serve_mod
from repro.runtime.train import make_train_step, opt_struct, param_struct


def _apply_overrides(cfg: ArchConfig, opt: dict) -> ArchConfig:
    """Perf-iteration config overrides (--opt key=val,...)."""
    fields = {f.name for f in dataclasses.fields(cfg)}
    repl = {}
    for k, v in opt.items():
        if k in fields:
            cur = getattr(cfg, k)
            repl[k] = type(cur)(v) if cur is not None else v
    return dataclasses.replace(cfg, **repl) if repl else cfg


def lower_cell(arch: str, shape: str, *, multi_pod: bool,
               path: str = "shortcut", opt: dict | None = None,
               dtype=jnp.bfloat16) -> dict:
    """Lower + compile one cell; returns the result record."""
    opt = opt or {}
    cfg = _apply_overrides(get(arch), opt)
    status = cell_status(cfg, shape)
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "path": path, "opt": opt, "status": status}
    if status == SKIP:
        rec["reason"] = "long_500k needs sub-quadratic decode; " \
            "full-attention arch (documented in DESIGN.md §5)"
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    spec = SHAPES[shape]
    t0 = time.time()

    grad_accum = int(opt.get("grad_accum", 1))
    remat = bool(int(opt.get("remat", 1)))
    factored = bool(int(opt.get("factored", cfg.num_params() > 3e10)))

    with shd.activate_mesh(mesh):
        if spec.kind == "train":
            p_struct = param_struct(cfg, dtype)
            o_struct = opt_struct(p_struct, factored=factored)
            batch = input_specs(cfg, shape)["batch"]
            p_specs = shd.param_specs(p_struct, mesh)
            # optimizer states mirror param sharding; scalars replicated
            o_specs = _opt_specs(o_struct, p_struct, mesh)
            b_specs = shd.batch_spec(batch, mesh)
            step = make_train_step(
                cfg, lr_fn=lambda s: wsd_schedule(
                    s, peak_lr=3e-4, warmup_steps=100, total_steps=10000),
                grad_accum=grad_accum, remat=remat, factored=factored).fn
            jitted = jax.jit(
                step,
                in_shardings=(p_specs, o_specs, b_specs),
                out_shardings=(p_specs, o_specs, None),
                donate_argnums=(0, 1))
            lowered = jitted.lower(p_struct, o_struct, batch)
            arg_structs = (p_struct, o_struct, batch)
            arg_specs = (p_specs, o_specs, b_specs)
            tokens = spec.global_batch * spec.seq_len
            model_flops = 6.0 * cfg.num_active_params() * tokens

        elif spec.kind == "prefill":
            p_struct = param_struct(cfg, dtype)
            batch = input_specs(cfg, shape)["batch"]
            p_specs = shd.param_specs(p_struct, mesh)
            b_specs = shd.batch_spec(batch, mesh)
            prefill = serve_mod.make_prefill_step(cfg, s_cap=spec.seq_len,
                                                  dtype=dtype)
            jitted = jax.jit(prefill, in_shardings=(p_specs, b_specs),
                             out_shardings=None)
            lowered = jitted.lower(p_struct, batch)
            arg_structs = (p_struct, batch)
            arg_specs = (p_specs, b_specs)
            tokens = spec.global_batch * spec.seq_len
            model_flops = 2.0 * cfg.num_active_params() * tokens

        else:  # decode
            p_struct = param_struct(cfg, dtype)
            p_specs = shd.param_specs(p_struct, mesh)
            ins = input_specs(cfg, shape, dtype=dtype)
            if path == "paged":
                from repro.kvcache import paged_cache as pc
                bs = int(opt.get("block_size", 16))
                B, S = spec.global_batch, spec.seq_len
                nblocks = B * (S // bs + 1)
                cache = jax.eval_shape(lambda: pc.cache_create(
                    cfg.num_layers, nblocks, bs, cfg.num_kv_heads,
                    cfg.resolved_head_dim, B, S // bs + 1, dtype))
                c_names = pc.PagedKVCache(
                    k_pool=["layer", "blocks", None, "kv_heads", "head_dim"],
                    v_pool=["layer", "blocks", None, "kv_heads", "head_dim"],
                    block_tables=["kv_seqs", None], seq_lens=["kv_seqs"],
                    free_ring=[None], free_head=[], free_count=[])
                c_specs = pc.PagedKVCache(*[
                    NamedSharding(mesh, shd.logical_spec(s.shape, n, mesh))
                    for s, n in zip(cache, c_names)])
                token = ins["token"]
                seq_ids = jax.ShapeDtypeStruct((spec.global_batch,), jnp.int32)
                tok_spec = NamedSharding(mesh, shd.logical_spec(
                    token.shape, ["batch"], mesh))
                step = serve_mod.make_paged_serve_step(cfg)
                jitted = jax.jit(
                    step, in_shardings=(p_specs, c_specs, tok_spec, tok_spec),
                    out_shardings=(tok_spec, c_specs), donate_argnums=(1,))
                lowered = jitted.lower(p_struct, cache, token, seq_ids)
                arg_structs = (p_struct, cache, token, seq_ids)
                arg_specs = (p_specs, c_specs, tok_spec, tok_spec)
            else:
                state = ins["state"]
                s_specs = serve_mod.decode_state_specs(cfg, state, mesh)
                token = ins["token"]
                tok_spec = NamedSharding(mesh, shd.logical_spec(
                    token.shape, ["batch"], mesh))
                step = serve_mod.make_serve_step(cfg)
                jitted = jax.jit(
                    step, in_shardings=(p_specs, s_specs, tok_spec),
                    out_shardings=(tok_spec, s_specs), donate_argnums=(1,))
                lowered = jitted.lower(p_struct, state, token)
                arg_structs = (p_struct, state, token)
                arg_specs = (p_specs, s_specs, tok_spec)
            tokens = spec.global_batch  # one token per sequence per step
            model_flops = 2.0 * cfg.num_active_params() * tokens

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    # trip-count-aware analysis over the optimized per-device HLO (XLA's
    # own cost_analysis counts while bodies once; see hlo_cost.py)
    totals = hlo_cost.analyze(compiled.as_text())
    cost = {"hlo_flops": totals.flops, "hlo_bytes": totals.bytes}
    xla_cost = hlo.cost_numbers(compiled)
    mem = hlo.memory_numbers(compiled)
    if mem["total_bytes"] == 0:
        mem["total_bytes"] = _sharded_arg_bytes(arg_structs, arg_specs)
        mem["argument_bytes"] = mem["total_bytes"]
        mem["source"] = "sharded-arg-fallback"
    terms = hlo.roofline_terms(cost["hlo_flops"], cost["hlo_bytes"],
                               totals.collective_bytes)
    per_device_model_flops = model_flops / chips

    rec.update({
        "chips": chips,
        "tokens_per_step": tokens,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "model_flops_global": model_flops,
        "model_flops_per_device": per_device_model_flops,
        **cost,
        "xla_cost_analysis": xla_cost,
        "useful_flops_ratio": (per_device_model_flops
                               / max(cost["hlo_flops"], 1.0)),
        "memory": mem,
        "collective_bytes": totals.collective_bytes,
        "collectives": {"bytes": totals.bytes_by_collective,
                        "count": totals.count_by_collective},
        "while_trips": totals.while_trips[:32],
        **terms,
    })
    # roofline fraction:
    #  - train/prefill (compute-dominated workloads): useful model compute
    #    time / the dominant-term time (an MFU-style number);
    #  - decode (memory-bound by nature): ideal bytes that MUST move per
    #    step (local params + live cache read once) / counted HLO bytes.
    bound = rec["step_s_lower_bound"]
    if spec.kind == "decode":
        ideal = _ideal_decode_bytes(arg_structs, arg_specs)
        rec["ideal_bytes_per_device"] = ideal
        rec["roofline_fraction"] = (
            (ideal / hlo.HBM_BW) / bound if bound > 0 else 0.0)
    else:
        rec["roofline_fraction"] = (
            per_device_model_flops / hlo.PEAK_FLOPS / bound
            if bound > 0 else 0.0)
    return rec


def _ideal_decode_bytes(arg_structs, arg_specs) -> int:
    """Local bytes a decode step cannot avoid touching once: parameters +
    KV/state cache (first two lowering args)."""
    return _sharded_arg_bytes(arg_structs[:2], arg_specs[:2])


def _opt_specs(o_struct, p_struct, mesh):
    """Optimizer state shardings mirror their parameter's sharding."""
    p_specs = shd.param_specs(p_struct, mesh)
    rep = NamedSharding(mesh, P())

    def v_spec(pspec, vleaf_tree):
        # factored dict {vr, vc}: derive from the param spec by dropping
        # the last / second-to-last dim's entry
        def reduce_spec(spec: NamedSharding, drop_axis: int, ndim: int):
            entries = list(spec.spec) + [None] * (ndim + 1 - len(spec.spec))
            del entries[drop_axis]
            while entries and entries[-1] is None:
                entries.pop()
            return NamedSharding(mesh, P(*entries))
        if isinstance(vleaf_tree, dict):
            nd = len(vleaf_tree["vr"].shape) + 1
            return {"vr": reduce_spec(pspec, nd - 1, nd - 1),
                    "vc": reduce_spec(pspec, nd - 2, nd - 1)}
        return pspec

    from repro.optim.adamw import AdamWState
    m_specs = p_specs
    v_specs = jax.tree.map(
        v_spec, p_specs, o_struct.v,
        is_leaf=lambda x: isinstance(x, NamedSharding))
    return AdamWState(step=rep, m=m_specs, v=v_specs)


def _sharded_arg_bytes(structs, specs) -> int:
    """Fallback per-device byte estimate: sum of local shard sizes."""
    total = 0
    flat_s = jax.tree.leaves(structs)
    flat_p = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, NamedSharding))
    for s, p in zip(flat_s, flat_p):
        if not hasattr(s, "shape"):
            continue
        n = s.dtype.itemsize
        for d in s.shape:
            n *= d
        if isinstance(p, NamedSharding):
            try:
                shard_shape = p.shard_shape(s.shape)
                n = s.dtype.itemsize
                for d in shard_shape:
                    n *= d
            except Exception:
                pass
        total += n
    return total


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", choices=["no", "yes", "both"],
                    default="no")
    ap.add_argument("--path", choices=["shortcut", "paged"],
                    default="shortcut")
    ap.add_argument("--opt", default="",
                    help="comma-separated key=val config overrides")
    ap.add_argument("--out", default="",
                    help="directory for one JSON per cell")
    args = ap.parse_args(argv)

    archs = list(cfg_base.ASSIGNED) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    pods = {"no": [False], "yes": [True], "both": [False, True]}[
        args.multi_pod]
    opt = dict(kv.split("=", 1) for kv in args.opt.split(",") if kv)

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                tag = f"{arch}/{shape}/{'2x16x16' if mp else '16x16'}" \
                    f"/{args.path}"
                try:
                    rec = lower_cell(arch, shape, multi_pod=mp,
                                     path=args.path, opt=opt)
                except Exception as e:
                    failures += 1
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "path": args.path, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    print(f"[FAIL] {tag}: {rec['error']}", flush=True)
                else:
                    if rec["status"] == SKIP:
                        print(f"[SKIP] {tag}: {rec['reason']}", flush=True)
                    else:
                        print(
                            f"[ OK ] {tag}: mem/dev="
                            f"{rec['memory']['total_bytes']/2**30:.2f}GiB "
                            f"compute={rec['compute_s']*1e3:.2f}ms "
                            f"memory={rec['memory_s']*1e3:.2f}ms "
                            f"collective={rec['collective_s']*1e3:.2f}ms "
                            f"dom={rec['dominant']} "
                            f"roofline={rec['roofline_fraction']:.3f} "
                            f"(compile {rec['compile_s']:.0f}s)",
                            flush=True)
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    opt_tag = "_" + "_".join(
                        f"{k}-{v}" for k, v in opt.items()) if opt else ""
                    fname = (f"{arch}__{shape}__"
                             f"{'2x16x16' if mp else '16x16'}__"
                             f"{args.path}{opt_tag}.json")
                    with open(os.path.join(args.out, fname), "w") as f:
                        json.dump(rec, f, indent=1, default=str)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
