"""End-to-end training driver.

Composes the full substrate: config registry -> data pipeline -> sharded
train step (remat/grad-accum/compression) -> async checkpointing ->
watchdog/straggler monitoring -> crash-loop restart.  On this CPU
container use ``--reduced``; on a real pod, point ``--mesh`` at the
production topology (the dry-run proves every cell lowers there).

  PYTHONPATH=src python -m repro.launch.train --arch internlm2_1_8b \
      --reduced --steps 200 --seq-len 128 --global-batch 8 \
      --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer, latest_step
from repro.configs import get
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed import sharding as shd
from repro.models import model as M
from repro.optim.adamw import adamw_init
from repro.optim.schedule import wsd_schedule
from repro.runtime.train import make_train_step
from repro.runtime.watchdog import Heartbeat, StragglerMonitor, Watchdog


def build_mesh(spec: str):
    dims = [int(x) for x in spec.split("x")]
    n = 1
    for d in dims:
        n *= d
    if n > len(jax.devices()):
        raise SystemExit(
            f"mesh {spec} needs {n} devices, have {len(jax.devices())} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N for "
            "CPU experimentation)")
    names = ("data", "model") if len(dims) == 2 else \
        ("pod", "data", "model")
    return jax.make_mesh(tuple(dims), names[:len(dims)])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--remat", type=int, default=1)
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dtype = jnp.float32 if args.dtype == "float32" else jnp.bfloat16
    mesh = build_mesh(args.mesh)
    pipe = SyntheticLM(cfg, DataConfig(args.seq_len, args.global_batch,
                                       seed=args.seed))

    params = M.init_params(cfg, jax.random.PRNGKey(args.seed), dtype)
    params = jax.device_put(params, shd.param_specs(params, mesh))
    opt = adamw_init(params)
    step_fn = make_train_step(
        cfg,
        lr_fn=lambda s: wsd_schedule(s, peak_lr=args.lr, warmup_steps=20,
                                     total_steps=args.steps),
        grad_accum=args.grad_accum, remat=bool(args.remat)).fn
    with shd.activate_mesh(mesh):
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))

        ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
        start = 0
        if ck is not None:
            last = latest_step(args.ckpt_dir)
            if last is not None:
                restored = ck.restore(last, {"params": params, "opt": opt})
                params, opt = restored["params"], restored["opt"]
                start = last
                print(f"[train] resumed from step {start}")

        hb = Heartbeat(0)
        monitor = StragglerMonitor()
        with Watchdog([hb], deadline_s=300.0,
                      on_dead=lambda d: print(f"[watchdog] DEAD: {d}")):
            for step in range(start, args.steps):
                t0 = time.perf_counter()
                batch = jax.device_put(pipe.batch(step),
                                       shd.batch_spec(pipe.batch(step),
                                                      mesh))
                params, opt, metrics = jitted(params, opt, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                hb.beat(step)
                if monitor.record(dt):
                    print(f"[straggler] step {step}: {dt:.3f}s vs median "
                          f"{monitor.median():.3f}s")
                if step % args.log_every == 0 or step == args.steps - 1:
                    tok_s = args.global_batch * args.seq_len / dt
                    print(f"[train] step {step:5d} loss {loss:8.4f} "
                          f"lr {float(metrics['lr']):.2e} "
                          f"gnorm {float(metrics['grad_norm']):8.3f} "
                          f"{dt * 1e3:7.1f} ms/step {tok_s:9.0f} tok/s",
                          flush=True)
                if not np.isfinite(loss):
                    raise RuntimeError(f"loss diverged at step {step}")
                if ck is not None and step and \
                        step % args.ckpt_every == 0:
                    ck.save_async(step, {"params": params, "opt": opt})
        if ck is not None:
            ck.save(args.steps, {"params": params, "opt": opt})
            print(f"[train] final checkpoint at step {args.steps}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
