"""Trip-count-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, but every model
here runs its layers (and microbatches, and loss chunks) under ``lax.scan``
— so FLOPs/bytes/collective-bytes would be undercounted by the trip count
(verified experimentally: an 8-step scan of matmuls reports 1/8 the flops of
the unrolled equivalent).  This module re-derives the three roofline
numerators directly from ``compiled.as_text()`` with loop multipliers:

  * **flops** — 2 x prod(result dims) x prod(contracting dims) per ``dot``
    (dots inside fusions included);
  * **bytes** — operands + result per *materializing* instruction (a fusion
    is one op over its operands/outputs, mirroring XLA's bytes-accessed
    convention; parameter/gte/tuple/bitcast/constant are free);
  * **collective bytes** — operand sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (``-start`` counted,
    ``-done`` skipped).

Operand shapes are resolved through a per-computation symbol table (the
text format prints operand *names* only).  While trip counts come from the
``known_trip_count`` backend config when present, else from the loop
condition's compare constant.  Conditional branches contribute their
maximum.  All numbers are PER-DEVICE (the input is the post-SPMD
partitioned module).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"%([\w\.\-]+)")
_FREE_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast",
             "constant", "iota", "after-all", "partition-id", "replica-id"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "divide", "erf", "logistic", "expm1", "log1p"}


def _dims(dims: str) -> list:
    return [int(d) for d in dims.split(",") if d]


def _shape_elems(dims: str) -> int:
    n = 1
    for d in _dims(dims):
        n *= d
    return n


def _type_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) type string."""
    return sum(_shape_elems(dims) * _DTYPE_BYTES.get(dt, 4)
               for dt, dims in _SHAPE_RE.findall(type_str)
               if dt in _DTYPE_BYTES)


@dataclass
class Instr:
    name: str
    opcode: str
    result_type: str
    operands: str
    attrs: str


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    types: dict = field(default_factory=dict)   # %name -> type string
    is_entry: bool = False

    def operand_names(self, inst: Instr) -> list:
        return _NAME_RE.findall(inst.operands)

    def operand_bytes(self, inst: Instr) -> int:
        return sum(_type_bytes(self.types.get(n, ""))
                   for n in self.operand_names(inst))


@dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    bytes_by_collective: dict = field(default_factory=dict)
    count_by_collective: dict = field(default_factory=dict)
    transcendental_elems: float = 0.0
    while_trips: list = field(default_factory=list)

    def add(self, other: "CostTotals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        self.transcendental_elems += other.transcendental_elems * mult
        self.while_trips.extend(other.while_trips)
        for k, v in other.bytes_by_collective.items():
            self.bytes_by_collective[k] = \
                self.bytes_by_collective.get(k, 0) + v * mult
        for k, v in other.count_by_collective.items():
            self.count_by_collective[k] = \
                self.count_by_collective.get(k, 0) + v * mult


# ---------------------------------------------------------------------------
# Parsing.
# ---------------------------------------------------------------------------

_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")


def _split_computations(hlo: str) -> dict:
    comps: dict = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        s = line.strip()
        if cur is None:
            m = _HEADER_RE.match(s)
            if m and s.endswith("{"):
                cur = Computation(name=m.group(2),
                                  is_entry=bool(m.group(1)))
            continue
        if s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        if " = " not in s:
            continue
        inst = _parse_instr(s)
        if inst:
            cur.instrs.append(inst)
            cur.types[inst.name] = inst.result_type
    return comps


def _parse_instr(s: str) -> Optional[Instr]:
    lhs, rhs = s.split(" = ", 1)
    name = lhs.strip().lstrip("ROOT").strip().lstrip("%")
    rhs = rhs.rstrip(",")
    if rhs.startswith("("):  # tuple result type
        depth = 0
        rtype, rest = None, None
        for i, c in enumerate(rhs):
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    rtype = rhs[:i + 1]
                    rest = rhs[i + 1:].lstrip()
                    break
        if rtype is None:
            return None
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        rtype = rhs[:sp]
        rest = rhs[sp + 1:].lstrip()
    par = rest.find("(")
    if par < 0:
        return None
    opcode = rest[:par].strip()
    depth = 0
    end = len(rest)
    for i in range(par, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operands = rest[par + 1:end]
    attrs = rest[end + 1:]
    return Instr(name=name, opcode=opcode, result_type=rtype,
                 operands=operands, attrs=attrs)


def _called_names(inst: Instr) -> dict:
    out: dict = {}
    for m in re.finditer(r"(to_apply|calls|body|condition)=%?([\w\.\-]+)",
                         inst.attrs):
        out[m.group(1)] = m.group(2)
    bm = re.search(r"branch_computations=\{([^}]*)\}", inst.attrs)
    if bm:
        out["branches"] = [b.strip().lstrip("%")
                           for b in bm.group(1).split(",")]
    return out


def _dot_flops(inst: Instr, comp: Computation) -> float:
    """2 x prod(result dims) x prod(lhs contracting dims)."""
    result_elems = sum(_shape_elems(d)
                       for _, d in _SHAPE_RE.findall(inst.result_type))
    names = comp.operand_names(inst)
    if not names:
        return 0.0
    lhs_type = comp.types.get(names[0], "")
    lhs_m = _SHAPE_RE.search(lhs_type)
    if not lhs_m:
        return 0.0
    lhs_dims = _dims(lhs_m.group(2))
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.attrs)
    contract = 1
    if cm:
        for i in _dims(cm.group(1)):
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * result_elems * contract


def _trip_count(inst: Instr, cond: Optional[Computation]) -> int:
    m = re.search(r'known_trip_count[^0-9]*"?(\d+)"?', inst.attrs)
    if m:
        return int(m.group(1))
    if cond is None:
        return 1
    consts = []
    for ci in cond.instrs:
        if ci.opcode == "constant":
            mm = re.match(r"^\s*(-?\d+)\s*$", ci.operands)
            if mm:
                consts.append(int(mm.group(1)))
    positive = [c for c in consts if c > 0]
    return max(positive) if positive else 1


# ---------------------------------------------------------------------------
# Cost walk.
# ---------------------------------------------------------------------------

def _fusion_flops(comp: Computation, comps: dict, depth: int = 0) -> float:
    """Dot flops inside a fused computation (bytes not counted there)."""
    if depth > 8:
        return 0.0
    total = 0.0
    for inst in comp.instrs:
        if inst.opcode == "dot":
            total += _dot_flops(inst, comp)
        called = _called_names(inst)
        for key in ("to_apply", "calls"):
            if key in called and called[key] in comps:
                total += _fusion_flops(comps[called[key]], comps, depth + 1)
    return total


def _comp_cost(comp: Computation, comps: dict, memo: dict) -> CostTotals:
    if comp.name in memo:
        return memo[comp.name]
    memo[comp.name] = CostTotals()  # cycle guard
    total = CostTotals()
    for inst in comp.instrs:
        op = inst.opcode
        called = _called_names(inst)
        if op == "while":
            body = comps.get(called.get("body", ""))
            cond = comps.get(called.get("condition", ""))
            trips = _trip_count(inst, cond)
            total.while_trips.append(trips)
            if body:
                total.add(_comp_cost(body, comps, memo), trips)
            if cond:
                total.add(_comp_cost(cond, comps, memo), trips)
            continue
        if op == "conditional":
            branches = [comps[b] for b in called.get("branches", [])
                        if b in comps]
            if branches:
                sub = [_comp_cost(b, comps, memo) for b in branches]
                total.add(max(sub, key=lambda c: max(c.flops, c.bytes)))
            total.bytes += _type_bytes(inst.result_type)
            continue
        if op == "fusion":
            fused = comps.get(called.get("calls", ""))
            if fused:
                total.flops += _fusion_flops(fused, comps)
                total.bytes += _fusion_bytes(inst, fused)
            else:
                total.bytes += comp.operand_bytes(inst) \
                    + _type_bytes(inst.result_type)
            continue
        if op == "call":
            sub = comps.get(called.get("to_apply", ""))
            if sub:
                total.add(_comp_cost(sub, comps, memo))
            continue
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES and not op.endswith("-done"):
            nbytes = comp.operand_bytes(inst)
            if nbytes == 0:
                nbytes = _type_bytes(inst.result_type)
            total.collective_bytes += nbytes
            total.bytes_by_collective[base] = \
                total.bytes_by_collective.get(base, 0) + nbytes
            total.count_by_collective[base] = \
                total.count_by_collective.get(base, 0) + 1
            total.bytes += nbytes + _type_bytes(inst.result_type)
            continue
        if op == "dot":
            total.flops += _dot_flops(inst, comp)
            total.bytes += comp.operand_bytes(inst) \
                + _type_bytes(inst.result_type)
            continue
        if op in _FREE_OPS or op.endswith("-done"):
            continue
        total.bytes += _instr_bytes(inst, comp)
        if op in _TRANSCENDENTAL:
            m = _SHAPE_RE.search(inst.result_type)
            if m:
                total.transcendental_elems += _shape_elems(m.group(2))
    memo[comp.name] = total
    return total


def _fusion_bytes(inst: Instr, fused: Computation) -> int:
    """Bytes accessed by a fusion, mirroring XLA's convention:

      * a parameter whose every use is a windowed read (dynamic-slice /
        gather) counts only the windows — per-layer weight slicing inside a
        scanned body must not charge the whole stacked array per iteration;
      * a parameter that is only the in-place target of dynamic-update-slice
        counts zero reads (the buffer is aliased; untouched data not moved);
      * if the fusion root is a dynamic-update-slice (possibly behind
        bitcasts), the write is the update window, not the full buffer.
    """
    by_name = {i.name: i for i in fused.instrs}
    uses_of: dict = {}
    for u in fused.instrs:
        for n in _NAME_RE.findall(u.operands):
            uses_of.setdefault(n, []).append(u)

    def through_casts(instr: Instr, down: bool) -> list:
        """Follow single-use convert/bitcast chains to effective
        consumers (down=True) — XLA-CPU sinks dtype converts around
        in-place updates; semantically the window update remains."""
        out, frontier, hops = [], [instr], 0
        while frontier and hops < 8:
            hops += 1
            nxt = []
            for i in frontier:
                us = uses_of.get(i.name, [])
                for u in us:
                    if u.opcode in ("convert", "bitcast", "copy"):
                        nxt.append(u)
                    else:
                        out.append(u)
            frontier = nxt
        return out

    reads = 0
    for p in fused.instrs:
        if p.opcode != "parameter":
            continue
        eff = through_casts(p, down=True)
        if not eff:
            continue
        def first_operand_is(u, name_set):
            names = fused.operand_names(u)
            return bool(names) and names[0] in name_set
        # names reachable from p through casts
        reach = {p.name}
        frontier, hops = [p], 0
        while frontier and hops < 8:
            hops += 1
            nxt = []
            for i in frontier:
                for u in uses_of.get(i.name, []):
                    if u.opcode in ("convert", "bitcast", "copy"):
                        reach.add(u.name)
                        nxt.append(u)
            frontier = nxt
        if all(u.opcode in ("dynamic-slice", "gather")
               and first_operand_is(u, reach) for u in eff):
            reads += sum(_type_bytes(u.result_type) for u in eff)
        elif all(u.opcode == "dynamic-update-slice"
                 and first_operand_is(u, reach) for u in eff):
            reads += 0  # aliased in-place target
        else:
            reads += _type_bytes(p.result_type)
    # write side: resolve the root through casts; DUS writes its window
    root = fused.instrs[-1] if fused.instrs else None
    seen = 0
    while root is not None and root.opcode in ("bitcast", "convert",
                                               "copy") and seen < 8:
        names = fused.operand_names(root)
        root = by_name.get(names[0]) if names else None
        seen += 1
    if root is not None and root.opcode == "dynamic-update-slice":
        names = fused.operand_names(root)
        upd_t = fused.types.get(names[1], "") if len(names) > 1 else ""
        upd = _type_bytes(upd_t) or _type_bytes(inst.result_type)
        writes = 2 * upd  # read update + write window
    else:
        writes = _type_bytes(inst.result_type)
    return reads + writes


def _instr_bytes(inst: Instr, comp: Computation) -> int:
    """Slice-aware bytes-accessed for one instruction (XLA convention:
    dynamic-slice/gather touch only the sliced window, not the buffer)."""
    op = inst.opcode
    res = _type_bytes(inst.result_type)
    if op == "dynamic-slice":
        return 2 * res                       # read window + write result
    if op == "dynamic-update-slice":
        names = comp.operand_names(inst)
        upd = _type_bytes(comp.types.get(names[1], "")) \
            if len(names) > 1 else res
        return 2 * upd                       # read update + write window
    if op == "gather":
        names = comp.operand_names(inst)
        idx = _type_bytes(comp.types.get(names[1], "")) \
            if len(names) > 1 else 0
        return 2 * res + idx
    if op == "scatter":
        names = comp.operand_names(inst)
        upd = _type_bytes(comp.types.get(names[-1], "")) \
            if names else res
        idx = _type_bytes(comp.types.get(names[1], "")) \
            if len(names) > 2 else 0
        return 2 * upd + idx
    if op in ("slice", "pad", "reverse", "broadcast", "reshape",
              "transpose", "copy", "convert"):
        return comp.operand_bytes(inst) + res
    return comp.operand_bytes(inst) + res


def breakdown(hlo_text: str, top: int = 25) -> dict:
    """Top flop- and byte-contributing instructions with loop multipliers —
    the dry-run 'profile' used by the §Perf iteration loop."""
    comps = _split_computations(hlo_text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {"flops": [], "bytes": []}
    frows: list = []
    brows: list = []

    def walk(comp: Computation, mult: float, depth: int):
        if depth > 16:
            return
        for inst in comp.instrs:
            called = _called_names(inst)
            op = inst.opcode
            if op == "while":
                body = comps.get(called.get("body", ""))
                cond = comps.get(called.get("condition", ""))
                trips = _trip_count(inst, cond)
                if body:
                    walk(body, mult * trips, depth + 1)
                continue
            if op == "call":
                sub = comps.get(called.get("to_apply", ""))
                if sub:
                    walk(sub, mult, depth + 1)
                continue
            if op == "fusion":
                fused = comps.get(called.get("calls", ""))
                if fused:
                    fl = _fusion_flops(fused, comps)
                    by = _fusion_bytes(inst, fused)
                    if fl:
                        frows.append((fl * mult, mult, inst.name,
                                      inst.result_type[:48]))
                    brows.append((by * mult, mult, "fusion:" + inst.name,
                                  inst.result_type[:48]))
                continue
            if op == "dot":
                fl = _dot_flops(inst, comp)
                frows.append((fl * mult, mult, inst.name,
                              inst.result_type[:48]))
                brows.append((_instr_bytes(inst, comp) * mult, mult,
                              "dot:" + inst.name, inst.result_type[:48]))
                continue
            if op in _FREE_OPS or op.endswith("-done"):
                continue
            brows.append((_instr_bytes(inst, comp) * mult, mult,
                          op + ":" + inst.name, inst.result_type[:48]))

    walk(entry, 1.0, 0)
    frows.sort(reverse=True)
    brows.sort(reverse=True)
    return {"flops": frows[:top], "bytes": brows[:top]}


def analyze(hlo_text: str) -> CostTotals:
    comps = _split_computations(hlo_text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        entry = max(comps.values(), key=lambda c: len(c.instrs),
                    default=None)
    if entry is None:
        return CostTotals()
    # descend only from the entry: subcomputations are reached through
    # their call sites (with the right multipliers)
    return _comp_cost(entry, comps, {})
