"""Serving driver: batched prefill + greedy decode over the shortcut or
paged KV path, with the version-gated async maintenance manager.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_4b --reduced \
      --batch 4 --prompt-len 32 --gen 16 --path shortcut
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.kvcache import paged_cache as pc
from repro.models import model as M
from repro.runtime.serve import (make_paged_serve_step, make_prefill_step,
                                 make_serve_step)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--path", choices=["shortcut", "paged"],
                    default="shortcut")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    B, S = args.batch, args.prompt_len
    s_cap = S + args.gen + 8
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed),
                           jnp.float32)
    key = jax.random.PRNGKey(args.seed + 1)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.input_mode == "embeddings":
        batch = {"embeddings": params["embed"][toks]}
    elif cfg.input_mode == "prefix_embeddings":
        batch["prefix_embeddings"] = jax.random.normal(
            key, (B, cfg.prefix_len, cfg.d_model), jnp.float32) * 0.02

    t0 = time.perf_counter()
    if args.path == "shortcut" or not cfg.has_attention:
        prefill = make_prefill_step(cfg, s_cap=s_cap, dtype=jnp.float32)
        serve = jax.jit(make_serve_step(cfg))
        logits, state = prefill(params, batch)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        outs = [tok]
        t0 = time.perf_counter()
        for _ in range(args.gen - 1):
            tok, state = serve(params, state, tok)
            outs.append(tok)
        jax.block_until_ready(tok)
    else:
        bs = 8
        cache = pc.cache_create(
            cfg.num_layers, num_blocks=B * (s_cap // bs + 1),
            block_size=bs, kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, max_seqs=B,
            max_blocks_per_seq=s_cap // bs + 1, dtype=jnp.float32)
        logits, caches = M.prefill_forward(params, cfg, batch)
        cache = pc.write_prefill(cache, jnp.arange(B), caches.k, caches.v)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0
        serve = jax.jit(make_paged_serve_step(cfg))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        seq_ids = jnp.arange(B, dtype=jnp.int32)
        outs = [tok]
        t0 = time.perf_counter()
        for _ in range(args.gen - 1):
            tok, cache = serve(params, cache, tok, seq_ids)
            outs.append(tok)
        jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.stack([np.asarray(t) for t in outs], axis=1)
    print(f"[serve/{args.path}] arch={cfg.name} B={B} prompt={S} "
          f"gen={args.gen}")
    print(f"  prefill: {t_prefill * 1e3:8.1f} ms "
          f"({B * S / t_prefill:9.0f} tok/s)")
    print(f"  decode:  {t_decode * 1e3:8.1f} ms "
          f"({B * (args.gen - 1) / max(t_decode, 1e-9):9.0f} tok/s)")
    print(f"  sample tokens[0]: {gen[0][:12].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
