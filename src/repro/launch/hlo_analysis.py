"""Roofline-term extraction from compiled dry-run artifacts.

``compiled.cost_analysis()`` supplies HLO FLOPs and bytes; collective bytes
are NOT in cost_analysis, so :func:`collective_bytes` parses the optimized
(post-SPMD) HLO text and sums operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op.

All numbers from a partitioned module are PER-DEVICE (local shapes), so the
prompt's ``term = global / (chips x peak)`` reduces to ``local / peak`` —
we report seconds directly.

Hardware model (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s ICI per link.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _shapes_bytes(text: str) -> int:
    return sum(_shape_bytes(dt, dims)
               for dt, dims in _SHAPE_RE.findall(text)
               if dt in _DTYPE_BYTES)


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def _call_operands(rhs: str, start: int) -> str:
    """Text inside the call parens beginning at rhs[start] == '('."""
    depth = 0
    for i in range(start, len(rhs)):
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
            if depth == 0:
                return rhs[start + 1:i]
    return rhs[start + 1:]


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective in optimized HLO text.

    ``-start`` async variants are counted once; ``-done`` is skipped."""
    out = CollectiveStats()
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        rhs = line.split("=", 1)[1]
        for base in _COLLECTIVES:
            hit = None
            for suffix in ("-start(", "("):
                token = " " + base + suffix
                idx = rhs.find(token)
                if idx >= 0:
                    hit = (idx, idx + len(token) - 1)
                    break
            if hit is None:
                continue
            idx, paren = hit
            operands = _call_operands(rhs, paren)
            nbytes = _shapes_bytes(operands)
            if nbytes == 0:  # e.g. operand named without shape: use result
                nbytes = _shapes_bytes(rhs[:idx])
            out.bytes_by_kind[base] = out.bytes_by_kind.get(base, 0) + nbytes
            out.count_by_kind[base] = out.count_by_kind.get(base, 0) + 1
            break
    return out


def cost_numbers(compiled) -> dict:
    """Normalize compiled.cost_analysis() across backends."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    if byts == 0.0:
        byts = sum(float(v) for k, v in ca.items()
                   if k.startswith("bytes accessed"))
    return {"hlo_flops": flops, "hlo_bytes": byts}


def memory_numbers(compiled, in_shardings=None, args=None) -> dict:
    """Per-device memory from memory_analysis(); CPU fallback: sum of
    sharded argument/output sizes."""
    try:
        ma = compiled.memory_analysis()
        out = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(ma, "generated_code_size_in_bytes", 0)),
        }
        out["total_bytes"] = (out["argument_bytes"] + out["output_bytes"]
                              + out["temp_bytes"])
        if out["total_bytes"] > 0:
            return out
    except Exception:
        pass
    return {"argument_bytes": 0, "output_bytes": 0, "temp_bytes": 0,
            "generated_code_bytes": 0, "total_bytes": 0}


def roofline_terms(hlo_flops: float, hlo_bytes: float,
                   collective_bytes: float) -> dict:
    """The three per-device roofline terms, in seconds."""
    compute_s = hlo_flops / PEAK_FLOPS
    memory_s = hlo_bytes / HBM_BW
    collective_s = collective_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=lambda k: terms[k])
    bound = max(terms.values())
    terms["dominant"] = dom
    terms["step_s_lower_bound"] = bound
    return terms
