"""Assigned input-shape registry and dry-run input specs.

Every (arch x shape) cell resolves here to (step kind, ShapeDtypeStruct
inputs).  ``decode_*``/``long_*`` lower ``serve_step`` (one new token against
a seq_len cache), ``prefill_32k`` lowers ``prefill_step``, ``train_4k``
lowers ``train_step`` — per the assignment contract.

``long_500k`` requires a sub-quadratic decode; pure full-attention archs are
*skipped* (returns SKIP) and the skip is documented in DESIGN.md §5.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.data.pipeline import make_batch_specs
from repro.runtime.serve import decode_state_struct

SKIP = "skip"


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_status(cfg: ArchConfig, shape: str) -> str:
    """'ok' or SKIP (with the documented reason encoded in DESIGN.md)."""
    spec = SHAPES[shape]
    if spec.name == "long_500k" and not cfg.is_subquadratic:
        return SKIP
    return "ok"


def input_specs(cfg: ArchConfig, shape: str, *, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell.

    train   -> {"batch": {...}}
    prefill -> {"batch": {...}}  (no labels)
    decode  -> {"state": DecodeState struct, "token": (B,) int32}
    """
    spec = SHAPES[shape]
    B, S = spec.global_batch, spec.seq_len
    if spec.kind in ("train", "prefill"):
        batch = make_batch_specs(cfg, S, B)
        if spec.kind == "prefill":
            batch.pop("labels", None)
        return {"batch": batch}
    # decode: the cache holds seq_len tokens; we feed one new token
    state = decode_state_struct(cfg, B, S, dtype)
    return {"state": state,
            "token": jax.ShapeDtypeStruct((B,), jnp.int32)}
